"""Run orchestrator: parallel/sequential equivalence, crash isolation,
shard merging, and baseline-compare verdicts (repro.core.orchestrate /
repro.core.baseline)."""
import json
import os
import textwrap

import pytest

from repro.core import baseline as bl
from repro.core.flags import FlagRegistry
from repro.core.hooks import HookChain
from repro.core.orchestrate import (OrchestratorOptions, ScopeShard,
                                    execute, merge_shards,
                                    scope_error_record)
from repro.core.registry import BenchmarkRegistry
from repro.core.runner import RunOptions, run_benchmarks
from repro.core.scope import ScopeManager

FAST = RunOptions(min_time=0.002)


def make_mgr(modules):
    mgr = ScopeManager(registry=BenchmarkRegistry(), flags=FlagRegistry(),
                       hooks=HookChain())
    mgr.load(modules)
    mgr.register_all()
    return mgr


def _ensure_src_on_child_path(monkeypatch, extra=None):
    parts = [os.path.abspath("src")]
    if extra:
        parts.append(str(extra))
    old = os.environ.get("PYTHONPATH")
    if old:
        parts.append(old)
    monkeypatch.setenv("PYTHONPATH", os.pathsep.join(parts))


# ---------------------------------------------------------------------------
# equivalence
# ---------------------------------------------------------------------------

def test_inline_merged_matches_sequential_runner():
    """Orchestrated inline run == plain run_benchmarks, record for record
    (names + schema; timings vary)."""
    mgr = make_mgr(["repro.scopes.example_scope"])
    seq = run_benchmarks(mgr.registry.filter(".*"), FAST, progress=False)
    res = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=1, run=FAST))
    assert sorted(res.doc) == ["benchmarks", "context"]
    assert [r["name"] for r in res.doc["benchmarks"]] == \
        [r["name"] for r in seq["benchmarks"]]
    assert [frozenset(r) for r in res.doc["benchmarks"]] == \
        [frozenset(r) for r in seq["benchmarks"]]


@pytest.mark.slow
def test_parallel_subprocess_matches_inline(monkeypatch, tmp_path):
    """--jobs 2 subprocess-isolated run: same names/schema as inline,
    shards persisted under results/<run-id>/."""
    _ensure_src_on_child_path(monkeypatch)
    mgr = make_mgr(["repro.scopes.example_scope",
                    "repro.scopes.instr_scope"])
    inline = execute(mgr, mgr.registry,
                     OrchestratorOptions(jobs=1, run=FAST))
    par = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=2, isolate="subprocess",
                                      run=FAST,
                                      results_dir=str(tmp_path),
                                      run_id="t1"))
    assert [s.status for s in par.shards] == ["ok", "ok"]
    assert [r["name"] for r in par.doc["benchmarks"]] == \
        [r["name"] for r in inline.doc["benchmarks"]]
    # schema equivalence: identical key-sets per record position
    assert [frozenset(r) for r in par.doc["benchmarks"]] == \
        [frozenset(r) for r in inline.doc["benchmarks"]]
    # persistence: one shard per scope + merged.json
    out = tmp_path / "t1"
    assert sorted(p.name for p in out.iterdir()) == \
        ["example.json", "instr.json", "merged.json"]
    merged = json.loads((out / "merged.json").read_text())
    assert [s["scope"] for s in merged["context"]["shards"]] == \
        ["example", "instr"]

    # scopeplot reads run directories and merged documents
    from repro.scopeplot import load
    bf = load(str(out))
    assert bf.scope_names() == ["example", "instr"]
    assert [s["status"] for s in bf.shards()] == ["ok", "ok"]
    assert len(bf.for_scope("example")) == \
        len(load(str(out / "example.json")))


# ---------------------------------------------------------------------------
# crash isolation
# ---------------------------------------------------------------------------

CRASHY = textwrap.dedent("""
    import os
    from repro.core import Scope, State, benchmark
    from repro.core.registry import BenchmarkRegistry

    NAME = "crashy"

    def _register(registry):
        @benchmark(scope=NAME, registry=registry)
        def die(state: State):
            os._exit(42)

    SCOPE = Scope(name=NAME, register=_register)
""")


@pytest.mark.slow
def test_crash_isolation_subprocess(monkeypatch, tmp_path):
    """A scope that kills its interpreter yields a crashed shard with an
    error record; sibling scopes still complete."""
    (tmp_path / "crashy_scope.py").write_text(CRASHY)
    monkeypatch.syspath_prepend(str(tmp_path))
    _ensure_src_on_child_path(monkeypatch, extra=tmp_path)
    mgr = make_mgr(["repro.scopes.example_scope", "crashy_scope"])
    res = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=2, isolate="subprocess",
                                      run=FAST))
    by = {s.scope: s for s in res.shards}
    assert by["example"].status == "ok"
    assert by["crashy"].status == "crashed"
    assert "42" in by["crashy"].error
    failed = [r for r in res.doc["benchmarks"]
              if r["name"] == "crashy/SCOPE_FAILED"]
    assert len(failed) == 1 and failed[0]["error_occurred"]
    assert any(r["name"].startswith("example/")
               for r in res.doc["benchmarks"])


FAULTY = textwrap.dedent("""
    from repro.core import Scope

    NAME = "faulty"

    def _register(registry):
        raise RuntimeError("registration exploded")

    SCOPE = Scope(name=NAME, register=_register)
""")


@pytest.mark.slow
def test_subprocess_distinguishes_error_from_crash(monkeypatch, tmp_path):
    """A worker that raises a normal exception reports an ERROR shard
    (with the traceback), not a CRASHED one."""
    (tmp_path / "faulty_scope.py").write_text(FAULTY)
    monkeypatch.syspath_prepend(str(tmp_path))
    _ensure_src_on_child_path(monkeypatch, extra=tmp_path)
    mgr = make_mgr(["faulty_scope"])
    # registration failure only manifests in the worker (parent-side
    # register_all already marked it unavailable) — dispatch explicitly
    from repro.core.orchestrate import _run_subprocess
    opts = OrchestratorOptions(jobs=1, isolate="subprocess", run=FAST)
    shard = _run_subprocess("faulty", "faulty_scope", opts)
    assert shard.status == "error"
    assert "registration exploded" in shard.error


@pytest.mark.slow
def test_crash_breaks_pool_but_run_recovers(monkeypatch, tmp_path):
    """Pool mode: an interpreter-killing worker breaks the
    ProcessPoolExecutor; unfinished scopes are retried in standalone
    subprocesses and the run still produces every shard."""
    (tmp_path / "crashy_scope.py").write_text(CRASHY)
    monkeypatch.syspath_prepend(str(tmp_path))
    _ensure_src_on_child_path(monkeypatch, extra=tmp_path)
    mgr = make_mgr(["repro.scopes.example_scope", "crashy_scope"])
    res = execute(mgr, mgr.registry,
                  OrchestratorOptions(jobs=2, isolate="pool", run=FAST))
    by = {s.scope: s for s in res.shards}
    assert set(by) == {"example", "crashy"}
    assert by["example"].status == "ok"
    assert by["crashy"].status == "crashed"


def test_import_failure_yields_error_shard(tmp_path):
    """A scope whose import fails is reported, not silently dropped —
    and inline siblings still run."""
    mgr = make_mgr(["repro.scopes.example_scope"])
    shards = [
        ScopeShard("example", "repro.scopes.example_scope", "ok",
                   run_benchmarks(mgr.registry.filter(".*"), FAST,
                                  progress=False)),
        ScopeShard("broken", "no.such.module", "error",
                   error="ModuleNotFoundError: no.such.module"),
    ]
    doc = merge_shards(shards, run_id="r1")
    assert doc["context"]["run_id"] == "r1"
    assert [s["status"] for s in doc["context"]["shards"]] == \
        ["ok", "error"]
    names = [r["name"] for r in doc["benchmarks"]]
    assert "broken/SCOPE_FAILED" in names


def test_scope_error_record_schema_matches_runner():
    """SCOPE_FAILED records carry the same schema as real error records
    so GB-JSON consumers need no special casing."""
    rec = scope_error_record(ScopeShard("x", "m", "crashed", error="boom"))
    for key in ("name", "run_name", "run_type", "repetitions",
                "repetition_index", "threads", "iterations", "real_time",
                "cpu_time", "time_unit", "error_occurred",
                "error_message"):
        assert key in rec
    assert rec["error_occurred"] is True
    assert "boom" in rec["error_message"]


# ---------------------------------------------------------------------------
# baseline comparison
# ---------------------------------------------------------------------------

def _doc(entries):
    """entries: {name: [times_us...]} -> GB-JSON document."""
    benchmarks = []
    for name, times in entries.items():
        for i, t in enumerate(times):
            benchmarks.append({
                "name": name, "run_name": name, "run_type": "iteration",
                "repetitions": len(times), "repetition_index": i,
                "threads": 1, "iterations": 100,
                "real_time": t, "cpu_time": t, "time_unit": "us",
            })
    return {"context": {}, "benchmarks": benchmarks}


def test_compare_flags_2x_slowdown():
    base = _doc({"s/a": [10.0, 10.1, 9.9], "s/b": [5.0, 5.1, 4.9]})
    new = _doc({"s/a": [20.0, 20.2, 19.8], "s/b": [5.1, 5.0, 4.9]})
    comps = {c.name: c for c in bl.compare_documents(base, new)}
    assert comps["s/a"].verdict == "regression"
    assert comps["s/a"].ratio == pytest.approx(2.0, rel=0.05)
    assert comps["s/b"].verdict == "similar"


def test_compare_stddev_gates_noisy_changes():
    """A 15% mean shift inside the noise band must NOT be flagged."""
    base = _doc({"s/noisy": [10.0, 14.0, 6.0]})
    new = _doc({"s/noisy": [11.5, 16.0, 7.0]})
    (c,) = bl.compare_documents(base, new)
    assert c.verdict == "similar" and not c.significant


def test_compare_improvement_added_removed_errors():
    base = _doc({"s/fast": [10.0, 10.0, 10.0], "s/gone": [1.0]})
    new = _doc({"s/fast": [5.0, 5.0, 5.0], "s/new": [1.0]})
    new["benchmarks"].append({
        "name": "s/err", "run_name": "s/err", "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 0, "real_time": 0.0, "cpu_time": 0.0,
        "time_unit": "us", "error_occurred": True, "error_message": "x"})
    base["benchmarks"].append(dict(new["benchmarks"][-1]))
    comps = {c.name: c for c in bl.compare_documents(base, new)}
    assert comps["s/fast"].verdict == "improvement"
    assert comps["s/gone"].verdict == "removed"
    assert comps["s/new"].verdict == "added"
    assert comps["s/err"].verdict == "errors"


def test_compare_units_normalized():
    base = {"context": {}, "benchmarks": [{
        "name": "s/x", "run_name": "s/x", "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 1, "real_time": 1.0, "cpu_time": 1.0,
        "time_unit": "ms"}]}
    new = {"context": {}, "benchmarks": [{
        "name": "s/x", "run_name": "s/x", "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 1, "real_time": 1000.0, "cpu_time": 1000.0,
        "time_unit": "us"}]}
    (c,) = bl.compare_documents(base, new)
    assert c.verdict == "similar"
    assert c.ratio == pytest.approx(1.0)


def test_compare_cli_exit_codes(tmp_path, capsys):
    base = _doc({"s/a": [10.0, 10.0, 10.1]})
    slow = _doc({"s/a": [20.0, 20.0, 20.2]})
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    pa.write_text(json.dumps(base))
    pb.write_text(json.dumps(slow))
    assert bl.compare_main([str(pa), str(pb)]) == 1
    assert "REGRESSION" in capsys.readouterr().out
    assert bl.compare_main([str(pa), str(pa)]) == 0


def test_gate_fails_on_vanished_or_errored_benchmarks():
    """A crashed scope (benchmarks vanish or turn into error records in
    the contender) must fail the CI gate, not slide through as
    'removed'/'added'."""
    base = _doc({"s/a": [10.0], "s/b": [10.0]})
    vanished = _doc({"s/a": [10.0]})
    assert [c.name for c in
            bl.gate_failures(bl.compare_documents(base, vanished))] == \
        ["s/b"]
    errored = _doc({"s/a": [10.0]})
    errored["benchmarks"].append({
        "name": "s/b", "run_name": "s/b", "run_type": "iteration",
        "repetitions": 1, "repetition_index": 0, "threads": 1,
        "iterations": 0, "real_time": 0.0, "cpu_time": 0.0,
        "time_unit": "us", "error_occurred": True, "error_message": "x"})
    assert [c.name for c in
            bl.gate_failures(bl.compare_documents(base, errored))] == \
        ["s/b"]
    # already broken in the baseline → not a new failure
    base_broken = _doc({"s/a": [10.0]})
    base_broken["benchmarks"].append(dict(errored["benchmarks"][-1]))
    assert bl.gate_failures(
        bl.compare_documents(base_broken, errored)) == []


def test_load_document_reads_interrupted_run_dir(tmp_path):
    """A run directory without merged.json (crash mid-run) still loads:
    the per-scope shards are concatenated."""
    a = _doc({"s/a": [1.0]})
    b = _doc({"s/b": [2.0]})
    (tmp_path / "a.json").write_text(json.dumps(a))
    (tmp_path / "b.json").write_text(json.dumps(b))
    doc = bl.load_document(str(tmp_path))
    assert [r["name"] for r in doc["benchmarks"]] == ["s/a", "s/b"]


def test_aggregates_are_not_double_counted():
    doc = _doc({"s/a": [10.0, 10.0]})
    doc["benchmarks"].append({
        "name": "s/a_mean", "run_name": "s/a", "run_type": "aggregate",
        "aggregate_name": "mean", "repetitions": 2, "threads": 1,
        "iterations": 100, "real_time": 10.0, "cpu_time": 10.0,
        "time_unit": "us"})
    stats = bl.collect_stats(doc)
    assert stats["s/a"].n == 2
