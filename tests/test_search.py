"""repro.core.search + repro.kernels.tuning: the tuner's search
strategies on a deterministic quadratic bowl (no measurement, no jax
arrays), and the tuned-default registry's precedence/validation
contract.  A small end-to-end `repro tune` run closes the loop."""
import json
import os

import pytest

from repro.core import ParamSpace, Params
from repro.core.search import (STRATEGIES, Trial, TrialError,
                               lower_is_better, oriented, pareto_front,
                               run_search, screening_plan)
from repro.kernels import tuning

# ---------------------------------------------------------------------------
# a deterministic 3-axis quadratic bowl: axis `a` dominates the
# objective, `b` matters less, `c` barely — minimum at (4, 8, 2)
# ---------------------------------------------------------------------------

BOWL = ParamSpace.product(a=[1, 2, 3, 4, 5],
                          b=[2, 4, 8, 16],
                          c=[1, 2, 3])


def bowl_eval(p):
    return {"real_time_s": (100.0 * (p.a - 4) ** 2
                            + 1.0 * (p.b - 8) ** 2
                            + 0.01 * (p.c - 2) ** 2
                            + 0.5)}


BOWL_MIN = {"a": 4, "b": 8, "c": 2}


def trial_keys(result):
    return [t.params.canonical() for t in result.trials]


# ---------------------------------------------------------------------------
# screening
# ---------------------------------------------------------------------------

def test_screening_plan_is_center_plus_axis_extremes():
    plan = screening_plan(BOWL)
    labels = [label for label, _ in plan]
    assert labels[0] == "center"
    # center = per-axis median value
    assert dict(plan[0][1]) == {"a": 3, "b": 4, "c": 2}
    # two extreme variants per axis (none collide with the center here)
    assert labels[1:] == ["a", "a", "b", "b", "c", "c"]
    for label, params in plan[1:]:
        assert params[label] in (min(BOWL.points(), key=lambda p: p[label])[label],
                                 max(BOWL.points(), key=lambda p: p[label])[label])


def test_screening_plan_respects_constraints():
    # prune exactly the geometric-center point (axis values unchanged)
    space = BOWL.where(lambda p: dict(p) != {"a": 3, "b": 4, "c": 2})
    plan = screening_plan(space)
    # falls back to the first in-space point, deterministically
    assert plan[0][1] == space.points()[0]
    members = {p.canonical() for p in space.points()}
    assert all(p.canonical() in members for _, p in plan)


def test_screening_ranks_most_sensitive_axis_first():
    result = run_search(BOWL, bowl_eval, strategy="screening", budget=7)
    axes = [axis for axis, _ in result.sensitivity]
    spans = [span for _, span in result.sensitivity]
    assert axes == ["a", "b", "c"]
    assert spans == sorted(spans, reverse=True)
    assert spans[0] > 100 * spans[2]


# ---------------------------------------------------------------------------
# hill-climb / auto
# ---------------------------------------------------------------------------

def test_auto_converges_to_the_bowl_minimum_within_budget():
    result = run_search(BOWL, bowl_eval, strategy="auto", budget=20, seed=0)
    assert result.best is not None
    assert dict(result.best.params) == BOWL_MIN
    assert result.best.metrics["real_time_s"] == pytest.approx(0.5)
    assert len(result.trials) <= 20


def test_hillclimb_only_converges_from_the_center():
    result = run_search(BOWL, bowl_eval, strategy="hillclimb", budget=30,
                        seed=1)
    assert dict(result.best.params) == BOWL_MIN


def test_budget_is_a_hard_ceiling_and_exhaustion_is_reported():
    result = run_search(BOWL, bowl_eval, strategy="auto", budget=3)
    assert len(result.trials) == 3
    assert result.exhausted
    full = run_search(BOWL, bowl_eval, strategy="screening", budget=50)
    assert not full.exhausted
    assert len(full.trials) == len(screening_plan(BOWL))


def test_cached_configs_do_not_consume_budget():
    calls = []

    def counting_eval(p):
        calls.append(p.canonical())
        return bowl_eval(p)

    result = run_search(BOWL, counting_eval, strategy="auto", budget=25)
    assert len(calls) == len(set(calls))          # never re-evaluated
    assert len(result.trials) == len(calls) <= 25


def test_same_seed_same_trial_sequence_different_seed_may_differ():
    a = run_search(BOWL, bowl_eval, strategy="auto", budget=12, seed=7)
    b = run_search(BOWL, bowl_eval, strategy="auto", budget=12, seed=7)
    assert trial_keys(a) == trial_keys(b)
    assert a.to_json() == b.to_json()


def test_rate_objectives_are_maximized():
    assert lower_is_better("real_time_s")
    assert not lower_is_better("flops_per_second")

    def rate_eval(p):
        return {"flops_per_second": float(p.a)}

    result = run_search(BOWL, rate_eval, objective="flops_per_second",
                        strategy="auto", budget=15, seed=0)
    assert result.best.params["a"] == 5


def test_trial_errors_consume_budget_and_are_recorded():
    def flaky(p):
        if p.a == 3:
            raise TrialError("boom")
        return bowl_eval(p)

    result = run_search(BOWL, flaky, strategy="screening", budget=7)
    errored = [t for t in result.trials if not t.ok]
    assert errored and all(t.error == "boom" for t in errored)
    assert result.best is not None
    assert result.best.params["a"] != 3


def test_everything_fails_yields_no_best():
    def always(p):
        raise TrialError("nope")

    result = run_search(BOWL, always, strategy="auto", budget=5)
    assert result.best is None
    assert all(not t.ok for t in result.trials)


def test_baseline_runs_first_when_in_space():
    base = Params({"a": 1, "b": 2, "c": 1})
    result = run_search(BOWL, bowl_eval, strategy="auto", budget=10,
                        baseline=base)
    assert result.baseline is not None
    assert result.baseline.index == 0
    assert result.trials[0].params.canonical() == base.canonical()


def test_cost_hints_steer_evaluation_order():
    plan = screening_plan(BOWL)
    expensive = plan[1][1].canonical()  # first a-extreme variant

    def hint(p):
        return 9.9 if p.canonical() == expensive else 0.1

    result = run_search(BOWL, bowl_eval, strategy="screening", budget=7,
                        cost_hint=hint)
    # the hinted-expensive variant is evaluated last of the variants
    assert trial_keys(result)[-1] == expensive


def test_invalid_strategy_and_budget_raise():
    with pytest.raises(ValueError):
        run_search(BOWL, bowl_eval, strategy="exhaustive")
    with pytest.raises(ValueError):
        run_search(BOWL, bowl_eval, budget=0)
    with pytest.raises(ValueError):
        run_search(ParamSpace.product(a=[1]).where(lambda p: False),
                   bowl_eval)
    assert set(STRATEGIES) == {"auto", "screening", "hillclimb"}


# ---------------------------------------------------------------------------
# pareto frontier
# ---------------------------------------------------------------------------

def _trial(i, time_s, rate=None, error=None):
    metrics = {} if error else {"real_time_s": time_s}
    if rate is not None and not error:
        metrics["flops_per_second"] = rate
    return Trial(index=i, phase="screen", params=Params({"a": i}),
                 metrics=metrics, error=error)


def test_pareto_front_is_orientation_aware():
    trials = [
        _trial(0, 1.0, rate=10.0),   # fast, slow rate — on the front
        _trial(1, 2.0, rate=20.0),   # slower but higher rate — on front
        _trial(2, 2.0, rate=5.0),    # dominated by 0 (and 1)
        _trial(3, 3.0, rate=20.0),   # dominated by 1
        _trial(4, 9.9, error="x"),   # failed — excluded
        _trial(5, 4.0),              # missing the rate — excluded
    ]
    front = pareto_front(trials, ["real_time_s", "flops_per_second"])
    assert [t.index for t in front] == [0, 1]


def test_pareto_front_single_objective_is_the_argmin():
    trials = [_trial(0, 3.0), _trial(1, 1.0), _trial(2, 2.0)]
    front = pareto_front(trials, ["real_time_s"])
    assert [t.index for t in front] == [1]


def test_oriented_scores():
    t = _trial(0, 2.0, rate=8.0)
    assert oriented("real_time_s", t) == 2.0
    assert oriented("flops_per_second", t) == -8.0
    assert oriented("missing_metric", t) == float("inf")
    assert oriented("real_time_s", _trial(1, 0, error="x")) == float("inf")


# ---------------------------------------------------------------------------
# tuned-default registry (repro.kernels.tuning)
# ---------------------------------------------------------------------------

@pytest.fixture
def tuned_dir(tmp_path, monkeypatch):
    monkeypatch.setenv(tuning.DIR_ENV, str(tmp_path))
    monkeypatch.delenv(tuning.DISABLE_ENV, raising=False)
    for kernel in tuning.kernels():
        for knob in tuning.KERNEL_KNOBS[kernel]:
            monkeypatch.delenv(
                f"REPRO_TUNED_{kernel.upper()}_{knob.upper()}",
                raising=False)
    tuning.invalidate_cache()
    yield tmp_path
    tuning.invalidate_cache()


def test_resolve_builtin_when_nothing_tuned(tuned_dir):
    assert tuning.resolve("matmul") == tuning.BUILTIN_DEFAULTS["matmul"]


def test_resolve_precedence_chain(tuned_dir, monkeypatch):
    # 4. artifact beats builtin
    tuning.write_tuned("matmul", {"config": {"bm": 128, "bn": 64, "bk": 32}})
    assert tuning.resolve("matmul") == {"bm": 128, "bn": 64, "bk": 32}
    # 3. env beats artifact (per knob)
    monkeypatch.setenv("REPRO_TUNED_MATMUL_BM", "256")
    assert tuning.resolve("matmul")["bm"] == 256
    assert tuning.resolve("matmul")["bn"] == 64
    # 2. override beats env
    with tuning.override("matmul", {"bm": 64}):
        assert tuning.resolve("matmul")["bm"] == 64
        # 1. explicit kwarg beats override
        assert tuning.resolve("matmul", bm=32)["bm"] == 32
    # override is restored on exit
    assert tuning.resolve("matmul")["bm"] == 256


def test_repro_tuned_off_disables_artifacts_only(tuned_dir, monkeypatch):
    tuning.write_tuned("rmsnorm", {"config": {"br": 1024}})
    assert tuning.resolve("rmsnorm") == {"br": 1024}
    monkeypatch.setenv(tuning.DISABLE_ENV, "off")
    assert tuning.resolve("rmsnorm") == tuning.BUILTIN_DEFAULTS["rmsnorm"]
    monkeypatch.setenv("REPRO_TUNED_RMSNORM_BR", "512")
    assert tuning.resolve("rmsnorm") == {"br": 512}    # env still applies


def test_non_integer_env_raises(tuned_dir, monkeypatch):
    monkeypatch.setenv("REPRO_TUNED_MATMUL_BM", "huge")
    with pytest.raises(ValueError, match="not an integer"):
        tuning.resolve("matmul")


def test_corrupt_artifact_degrades_to_builtin(tuned_dir):
    path = tuning.tuned_path("ssd_scan")
    os.makedirs(os.path.dirname(path))
    with open(path, "w") as fh:
        fh.write("{not json")
    assert tuning.resolve("ssd_scan") == tuning.BUILTIN_DEFAULTS["ssd_scan"]


def test_write_tuned_is_byte_deterministic(tuned_dir, tmp_path):
    payload = {"config": {"bq": 128, "bk": 256}, "kernel": "flash_attention",
               "objective": "real_time_s", "seed": 0}
    p1 = tuning.write_tuned("flash_attention", payload,
                            path=str(tmp_path / "one.json"))
    p2 = tuning.write_tuned("flash_attention", dict(reversed(payload.items())),
                            path=str(tmp_path / "two.json"))
    with open(p1, "rb") as a, open(p2, "rb") as b:
        assert a.read() == b.read()


def test_write_tuned_validates_payload(tuned_dir):
    with pytest.raises(ValueError, match="config"):
        tuning.write_tuned("matmul", {"kernel": "matmul"})
    with pytest.raises(ValueError, match="no knob"):
        tuning.write_tuned("matmul", {"config": {"tile": 8}})
    with pytest.raises(ValueError, match="unknown tunable kernel"):
        tuning.write_tuned("conv", {"config": {"bm": 8}})


def test_override_rejects_unknown_knobs():
    with pytest.raises(ValueError, match="no knob"):
        with tuning.override("rmsnorm", {"bm": 8}):
            pass


def test_validate_blocks_reports_every_problem():
    with pytest.raises(ValueError) as exc:
        tuning.validate_blocks("matmul", {"bm": 48, "bn": -1, "bk": 64},
                               dims={"bm": 128, "bn": 128, "bk": 128})
    msg = str(exc.value)
    assert "bm=48" in msg and "does not divide" in msg
    assert "bn=-1" in msg and "positive" in msg
    assert "bk=64" not in msg
    assert "repro tune" in msg            # remediation, not a stack trace


def test_validate_blocks_enforces_the_vmem_budget(monkeypatch):
    monkeypatch.setenv(tuning.VMEM_ENV, str(1024))
    with pytest.raises(ValueError, match="VMEM"):
        tuning.validate_blocks("matmul", {"bm": 128}, dims={"bm": 128},
                               vmem_bytes=2048.0)
    tuning.validate_blocks("matmul", {"bm": 128}, dims={"bm": 128},
                           vmem_bytes=512.0)


# ---------------------------------------------------------------------------
# end-to-end: `python -m repro tune` on the real mxu/matmul family
# ---------------------------------------------------------------------------

def tune_cli(args):
    """One tune_main call against a pristine global registry (the
    process-global REGISTRY would otherwise accumulate registrations
    across calls and collide) with FLAGS snapshotted."""
    from repro.core.flags import FLAGS
    from repro.core.registry import REGISTRY
    from repro.core.tune import tune_main
    specs, values = dict(FLAGS._specs), dict(FLAGS._values)
    saved = dict(REGISTRY._benchmarks)
    REGISTRY._benchmarks.clear()
    try:
        return tune_main(args)
    finally:
        REGISTRY._benchmarks.clear()
        REGISTRY._benchmarks.update(saved)
        FLAGS._specs.clear(), FLAGS._specs.update(specs)
        FLAGS._values.clear(), FLAGS._values.update(values)


def test_tune_cli_end_to_end(tuned_dir, tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    rc = tune_cli(["mxu/matmul", "--budget", "2", "--seed", "0",
                    "--strategy", "hillclimb", "--no-report",
                    "--results-dir", str(tmp_path / "results"),
                    "--run-id", "tunetest", "--enable-scope", "mxu",
                    "--benchmark_min_time", "0.001"])
    assert rc == 0
    artifact = json.load(open(tuning.tuned_path("matmul")))
    assert set(artifact["config"]) == {"bm", "bn", "bk"}
    assert artifact["source"]["family"] == "mxu/matmul"
    assert artifact["source"]["run_id"] == "tunetest"
    summary = json.load(open(tmp_path / "results" / "tunetest" / "tune.json"))
    assert summary["kernel"] == "matmul"
    assert summary["best"]["params"] == artifact["config"]
    assert len(summary["search"]["trials"]) <= 3  # budget + exempt baseline
    with open(tmp_path / "results" / "history.jsonl") as fh:
        records = [json.loads(line) for line in fh]
    assert records and all(r.get("tag") == "tune" for r in records)
    assert all(r["name"].startswith("tune/matmul/") for r in records)
    # the written artifact now *is* the kernel default
    tuning.invalidate_cache()
    assert tuning.resolve("matmul") == artifact["config"]


def test_tune_cli_list_and_bad_family(tuned_dir, capsys):
    assert tune_cli(["--list"]) == 0
    out = capsys.readouterr().out
    assert "mxu/matmul" in out and "nn/rmsnorm" in out
    assert tune_cli(["mxu/nope"]) == 1
    # the miss prints the tunable-family listing as a hint
    assert "mxu/matmul" in capsys.readouterr().out
