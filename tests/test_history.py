"""Run-history store: append/verdicts/dedup, windowed queries, drift
detection, and the history-as-baseline loader (repro.core.history)."""
import pytest

from repro.core import history as hist
from repro.core.baseline import load_document, compare_documents
from repro.core.sysinfo import context_digest

CTX = {"run_id": "r?", "date": "2026-07-31T00:00:00",
       "host_name": "fixturehost", "machine": "x86_64", "num_cpus": 8,
       "jax_version": "0.0-test", "backend": "cpu", "device_count": 1,
       "device_kind": "cpu", "target_hardware": "tpu_v5e",
       "scope_version": "1.0.0-jax"}


def make_doc(run_id, means, date="2026-07-31T00:00:00", errors=()):
    """A minimal merged GB-JSON document with fixed context."""
    ctx = dict(CTX, run_id=run_id, date=date)
    benchmarks = []
    for name, mean in means.items():
        benchmarks.append({
            "name": name, "run_name": name, "run_type": "iteration",
            "repetitions": 1, "repetition_index": 0, "threads": 1,
            "iterations": 1, "real_time": mean, "cpu_time": mean,
            "time_unit": "s"})
    for name in errors:
        benchmarks.append({
            "name": name, "run_name": name, "run_type": "iteration",
            "repetitions": 1, "repetition_index": 0, "threads": 1,
            "iterations": 0, "real_time": 0.0, "cpu_time": 0.0,
            "time_unit": "s", "error_occurred": True,
            "error_message": "boom"})
    return {"context": ctx, "benchmarks": benchmarks}


def test_append_and_verdicts(tmp_path):
    d = str(tmp_path)
    r1 = hist.append_run(d, make_doc("r1", {"s/a": 1.0, "s/b": 2.0}))
    assert [r["verdict"] for r in r1] == ["new", "new"]
    assert all(r["run_id"] == "r1" for r in r1)
    assert all(r["ts"] == "2026-07-31T00:00:00" for r in r1)
    assert all(r["sysinfo"] == context_digest(CTX) for r in r1)

    # +5% similar, +50% regression, -50% improvement vs previous record
    r2 = hist.append_run(d, make_doc("r2", {"s/a": 1.05, "s/b": 3.0}))
    assert {r["name"]: r["verdict"] for r in r2} == \
        {"s/a": "similar", "s/b": "regression"}
    r3 = hist.append_run(d, make_doc("r3", {"s/a": 1.05, "s/b": 1.5}))
    assert {r["name"]: r["verdict"] for r in r3}["s/b"] == "improvement"
    assert r3[0]["ratio"] == pytest.approx(1.0)

    records = hist.load_history(hist.history_path(d))
    assert len(records) == 6
    assert hist.run_ids(records) == ["r1", "r2", "r3"]
    assert [r["run_id"] for r in hist.series(records, "s/a")] == \
        ["r1", "r2", "r3"]


def test_append_dedups_by_run_id(tmp_path):
    d = str(tmp_path)
    assert hist.append_run(d, make_doc("r1", {"s/a": 1.0}))
    # a resumed run merges twice; the second merge must not re-append
    assert hist.append_run(d, make_doc("r1", {"s/a": 9.9})) == []
    assert len(hist.load_history(hist.history_path(d))) == 1


def test_errored_instances_recorded(tmp_path):
    d = str(tmp_path)
    recs = hist.append_run(d, make_doc("r1", {"s/a": 1.0},
                                       errors=["s/bad"]))
    by_name = {r["name"]: r for r in recs}
    assert by_name["s/bad"]["verdict"] == "errored"
    assert by_name["s/bad"]["mean_s"] is None
    assert by_name["s/bad"]["errors"] == 1


def test_torn_line_skipped(tmp_path):
    d = str(tmp_path)
    hist.append_run(d, make_doc("r1", {"s/a": 1.0}))
    path = hist.history_path(d)
    with open(path, "a") as f:
        f.write('{"run_id": "r2", "name": "s/a", "mea')   # torn write
    records = hist.load_history(path)
    assert len(records) == 1 and records[0]["run_id"] == "r1"


def test_corrupt_lines_skipped_not_raised(tmp_path):
    """Complete-but-garbage lines (bad JSON, undecodable bytes, non-dict
    JSON, records without a name) warn and skip — one bad write must
    never take down every consumer of the whole history."""
    d = str(tmp_path)
    hist.append_run(d, make_doc("r1", {"s/a": 1.0}))
    path = hist.history_path(d)
    with open(path, "ab") as f:
        f.write(b'{"run_id": "rX", "name": "s/a", "mean_s":\n')  # bad JSON
        f.write(b"\xff\xfe garbage bytes \xff\n")           # undecodable
        f.write(b'[1, 2, 3]\n')                             # not a dict
        f.write(b'{"run_id": "rY"}\n')                      # no name
        f.write(b'\n')                                      # blank
    hist.append_run(d, make_doc("r2", {"s/a": 1.01}))
    records = hist.load_history(path)
    assert hist.run_ids(records) == ["r1", "r2"]
    assert len(records) == 2
    # scan and store-eligible loader agree on the surviving set
    assert hist.scan_history(path) == records


def test_window_document_pools_runs(tmp_path):
    d = str(tmp_path)
    for i, mean in enumerate([1.0, 1.1, 0.9, 1.0, 1.2, 1.05]):
        hist.append_run(d, make_doc(f"r{i}", {"s/a": mean}))
    records = hist.load_history(hist.history_path(d))
    doc = hist.window_document(records, window=4)
    times = [b["real_time"] for b in doc["benchmarks"]]
    assert times == [0.9, 1.0, 1.2, 1.05]          # last 4 runs only
    assert all(b["time_unit"] == "s" for b in doc["benchmarks"])
    assert doc["benchmarks"][0]["run_name"] == "s/a"


def test_load_document_reads_history_as_windowed_baseline(tmp_path):
    d = str(tmp_path)
    for i in range(3):
        hist.append_run(d, make_doc(f"r{i}", {"s/a": 1.0 + 0.01 * i}))
    doc = load_document(hist.history_path(d))
    assert len(doc["benchmarks"]) == 3
    assert doc["context"]["history_window"] == hist.DEFAULT_WINDOW
    # and it composes with compare_documents like any other document
    comps = compare_documents(doc, make_doc("new", {"s/a": 5.0}))
    assert [c.verdict for c in comps] == ["regression"]


def test_detect_drift_catches_slow_drift(tmp_path):
    """Each consecutive step is 'similar' (+4% < 10%), but the latest
    run has drifted >10% past the window mean — exactly the case
    single-run compare misses."""
    d = str(tmp_path)
    means = [1.0, 1.04, 1.08, 1.12, 1.17]
    for i, m in enumerate(means):
        recs = hist.append_run(d, make_doc(f"r{i}", {"s/a": m}))
        if i:
            assert recs[0]["verdict"] == "similar"    # step-wise: quiet
    records = hist.load_history(hist.history_path(d))
    comps = hist.detect_drift(records, window=4)
    assert [c.verdict for c in comps] == ["regression"]
    # both-constant history stays quiet
    comps = hist.detect_drift(
        [r for r in records if r["run_id"] in ("r0", "r1")], window=4)
    assert [c.verdict for c in comps] == ["similar"]


def test_detect_drift_needs_two_runs(tmp_path):
    d = str(tmp_path)
    hist.append_run(d, make_doc("r1", {"s/a": 1.0}))
    assert hist.detect_drift(
        hist.load_history(hist.history_path(d))) == []


def test_single_shot_regression_not_masked_by_old_noise(tmp_path):
    """A noisy multi-repetition previous record must not sigma-mask a
    single-shot regression — matching compare_documents, the sigma gate
    only applies when BOTH sides have repetition data."""
    d = str(tmp_path)
    doc1 = make_doc("r1", {})
    doc1["benchmarks"] = [
        {"name": "s/a", "run_name": "s/a", "run_type": "iteration",
         "repetitions": 3, "repetition_index": i, "threads": 1,
         "iterations": 1, "real_time": t, "cpu_time": t, "time_unit": "s"}
        for i, t in enumerate([0.7, 1.0, 1.3])]     # mean 1.0, noisy
    r1 = hist.append_run(d, doc1)
    assert r1[0]["n"] == 3 and r1[0]["stddev_s"] > 0
    r2 = hist.append_run(d, make_doc("r2", {"s/a": 1.4}))   # +40%, n=1
    assert r2[0]["verdict"] == "regression"


def test_cross_machine_records_never_compared(tmp_path):
    """Records with a different sysinfo digest are not a valid
    'previous' and are excluded from windowed baselines."""
    d = str(tmp_path)
    hist.append_run(d, make_doc("r1", {"s/a": 1.0}))
    other = make_doc("r2", {"s/a": 5.0})
    other["context"]["host_name"] = "другое"      # different machine
    r2 = hist.append_run(d, other)
    assert r2[0]["verdict"] == "new"              # not a 5x regression
    records = hist.load_history(hist.history_path(d))
    # windowed baseline folds only the newest digest's records
    doc = hist.window_document(records)
    assert [b["real_time"] for b in doc["benchmarks"]] == [5.0]
    assert doc["context"]["history_sysinfo"] == r2[0]["sysinfo"]
    # drift: the latest run has no same-digest prior window
    assert all(c.verdict == "added"
               for c in hist.detect_drift(records))


def test_context_digest_stable_and_sensitive():
    a = context_digest(CTX)
    assert a == context_digest(dict(CTX, date="1999-01-01",
                                    run_id="other"))   # run facts ignored
    assert a != context_digest(dict(CTX, host_name="elsewhere"))
    assert len(a) == 12


def test_orchestrator_appends_history(tmp_path):
    """A persisted run lands in <results-dir>/history.jsonl at merge
    time; a second run's records carry verdicts vs the first."""
    from repro.core.flags import FlagRegistry
    from repro.core.hooks import HookChain
    from repro.core.orchestrate import OrchestratorOptions, execute
    from repro.core.registry import BenchmarkRegistry
    from repro.core.runner import RunOptions
    from repro.core.scope import ScopeManager

    results = str(tmp_path / "results")
    for rid in ("h1", "h2"):
        mgr = ScopeManager(registry=BenchmarkRegistry(),
                           flags=FlagRegistry(), hooks=HookChain())
        mgr.load(["repro.scopes.example_scope"])
        mgr.register_all()
        execute(mgr, mgr.registry, OrchestratorOptions(
            jobs=1, isolate="inline", shard_grain="benchmark",
            run=RunOptions(min_time=0.002), results_dir=results,
            run_id=rid))
    records = hist.load_history(hist.history_path(results))
    assert hist.run_ids(records) == ["h1", "h2"]
    for rec in hist.for_run(records, "h2"):
        assert rec["verdict"] in ("similar", "regression", "improvement")
        assert rec["mean_s"] > 0
