"""repro.core.lint: every built-in rule has a triggering family and a
clean family; the CLI gates exit codes; and the whole pass provably
never executes a benchmark body."""
import json
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParamSpace, Scope, State
from repro.core.benchmark import Benchmark
from repro.core.flags import FlagRegistry
from repro.core.hooks import HookChain
from repro.core.lint import (RULES, FamilyAnalysis, FamilyRule, LintReport,
                             Rule, lint_main, parse_rules, register_rule,
                             run_lint)
from repro.core.registry import BenchmarkRegistry, register_benchmark
from repro.core.scope import BUILTIN_SCOPES, ScopeManager


def reg():
    return BenchmarkRegistry()


def rules_of(report, family=None):
    return sorted({f.rule for f in report.findings
                   if family is None or f.family == family})


def lint(registry, **kwargs):
    kwargs.setdefault("compile_checks", False)
    return run_lint(registry.all(), **kwargs)


@pytest.fixture
def no_body_runs(monkeypatch):
    """Poison the timed loop: any benchmark body that starts iterating
    blows up the test — the linter must never get there."""
    def boom(self):
        raise AssertionError("lint executed a benchmark body")
    monkeypatch.setattr(State, "keep_running", boom)


# ---------------------------------------------------------------------------
# SCOPE000 — unanalyzable body
# ---------------------------------------------------------------------------

def test_scope000_triggers_on_sourceless_body(no_body_runs):
    r = reg()
    ns = {}
    exec("def body(state):\n"
         "    while state.keep_running():\n"
         "        pass\n", ns)
    register_benchmark("nosource", ns["body"], scope="s", registry=r)
    assert "SCOPE000" in rules_of(lint(r))


def test_scope000_clean_on_plain_function(no_body_runs):
    r = reg()

    def body(state):
        while state.keep_running():
            state.deliver(1)
        state.set_items_processed(1)
    register_benchmark("plain", body, scope="s", registry=r)
    assert lint(r).findings == []


# ---------------------------------------------------------------------------
# SCOPE101 — unfenced async body
# ---------------------------------------------------------------------------

def _quietly(b: Benchmark) -> Benchmark:
    """Silence the rules a minimal body would otherwise trip."""
    b.set_sync(lambda ctx: None)
    return b


def test_scope101_triggers_without_deliver_or_sync(no_body_runs):
    r = reg()

    def body(state):
        while state.keep_running():
            pass
        state.set_items_processed(1)
    register_benchmark("unfenced", body, scope="s", registry=r)
    assert rules_of(lint(r)) == ["SCOPE101"]


def test_scope101_clean_when_delivering(no_body_runs):
    r = reg()

    def body(state):
        while state.keep_running():
            state.deliver(41 + 1)
        state.set_items_processed(1)
    register_benchmark("delivers", body, scope="s", registry=r)
    assert lint(r).findings == []


def test_scope101_clean_with_sync_fence_or_manual_time(no_body_runs):
    r = reg()

    def fenced(state):
        while state.keep_running():
            pass
        state.set_items_processed(1)
    _quietly(register_benchmark("fenced", fenced, scope="s", registry=r))

    def manual(state):
        while state.keep_running():
            state.set_iteration_time(1e-3)
        state.set_items_processed(1)
    register_benchmark("manual", manual, scope="s",
                       registry=r).manual_time()
    assert lint(r).findings == []


# ---------------------------------------------------------------------------
# SCOPE102 — allocation/compilation inside the timed loop
# ---------------------------------------------------------------------------

def test_scope102_triggers_on_alloc_in_timed_loop(no_body_runs):
    r = reg()

    def body(state):
        while state.keep_running():
            x = jnp.ones(16)
            state.deliver(jax.jit(lambda v: v * 2)(x))
        state.set_items_processed(16)
    register_benchmark("hot_alloc", body, scope="s", registry=r)
    found = [f for f in lint(r).findings if f.rule == "SCOPE102"]
    assert len(found) == 2          # jnp.ones and jax.jit
    assert all(f.severity == "error" for f in found)


def test_scope102_clean_when_setup_is_outside_the_loop(no_body_runs):
    r = reg()

    def body(state):
        x = jnp.ones(16)            # before the first keep_running():
        fn = jax.jit(lambda v: v * 2)   # untimed by construction
        while state.keep_running():
            state.deliver(fn(x))
        state.set_items_processed(16)
    register_benchmark("cold_alloc", body, scope="s", registry=r)
    assert lint(r).findings == []


# ---------------------------------------------------------------------------
# SCOPE103 — dead parameter axes
# ---------------------------------------------------------------------------

def test_scope103_triggers_on_unread_axis(no_body_runs):
    r = reg()

    def body(state):
        n = state.params.n
        while state.keep_running():
            state.deliver(n * 2)
        state.set_items_processed(n)
    b = register_benchmark("deadaxis", body, scope="s", registry=r)
    b.param_space(ParamSpace.product(dtype=["f32", "f64"], n=[4]))
    found = [f for f in lint(r).findings if f.rule == "SCOPE103"]
    assert len(found) == 1 and "'dtype'" in found[0].message


def test_scope103_clean_when_fixture_reads_the_axis(no_body_runs):
    r = reg()

    def setup(params):
        return np.zeros(params.n, dtype=params.dtype)

    def body(state):
        x = state.fixture
        while state.keep_running():
            state.deliver(x + 1)
        state.set_items_processed(state.params.n)
    b = register_benchmark("liveaxis", body, scope="s", registry=r)
    b.param_space(ParamSpace.product(dtype=["f32"], n=[4]))
    b.set_fixture(setup)
    assert lint(r).findings == []


def test_scope103_stays_quiet_when_params_escape(no_body_runs):
    r = reg()

    def helper(p):
        return p

    def body(state):
        cfg = helper(state.params)      # analyzer can't see inside
        while state.keep_running():
            state.deliver(cfg)
        state.set_items_processed(1)
    b = register_benchmark("escapes", body, scope="s", registry=r)
    b.param_space(ParamSpace.product(dtype=["f32"], n=[4]))
    assert rules_of(lint(r)) == []


def test_scope103_reads_via_state_range_and_alias(no_body_runs):
    r = reg()

    def legacy(state):
        n = state.range(0)
        while state.keep_running():
            state.deliver(n)
        state.set_items_processed(n)
    b = register_benchmark("legacy_range", legacy, scope="s", registry=r)
    b.args([4]).set_arg_names(["n"])

    def aliased(state):
        p = state.params
        while state.keep_running():
            state.deliver(p.n)
        state.set_items_processed(p.n)
    b2 = register_benchmark("aliased", aliased, scope="s", registry=r)
    b2.param_space(ParamSpace.product(n=[4]))
    assert lint(r).findings == []


# ---------------------------------------------------------------------------
# SCOPE104 — no throughput signal
# ---------------------------------------------------------------------------

def test_scope104_triggers_without_counters(no_body_runs):
    r = reg()

    def body(state):
        while state.keep_running():
            state.deliver(1)
    register_benchmark("bare_time", body, scope="s", registry=r)
    assert rules_of(lint(r)) == ["SCOPE104"]


def test_scope104_clean_with_any_signal(no_body_runs):
    r = reg()

    def with_bytes(state):
        while state.keep_running():
            state.deliver(1)
        state.set_bytes_processed(64)
    register_benchmark("with_bytes", with_bytes, scope="s", registry=r)

    def with_counter(state):
        while state.keep_running():
            state.deliver(1)
        state.counters["flops"] = 2.0
    register_benchmark("with_counter", with_counter, scope="s", registry=r)
    assert lint(r).findings == []


# ---------------------------------------------------------------------------
# SCOPE105 — wall-clock reads in the body
# ---------------------------------------------------------------------------

def test_scope105_triggers_on_host_clock(no_body_runs):
    r = reg()

    def body(state):
        import time
        t0 = time.perf_counter()
        while state.keep_running():
            state.deliver(time.perf_counter() - t0)
        state.set_items_processed(1)
    register_benchmark("clocky", body, scope="s", registry=r)
    found = [f for f in lint(r).findings if f.rule == "SCOPE105"]
    assert len(found) == 2 and found[0].severity == "error"


def test_scope105_exempts_manual_time_families(no_body_runs):
    r = reg()

    def body(state):
        import time
        while state.keep_running():
            t0 = time.perf_counter()
            state.deliver(1)
            state.set_iteration_time(time.perf_counter() - t0)
        state.set_items_processed(1)
    register_benchmark("manual_clock", body, scope="s",
                       registry=r).manual_time()
    assert lint(r).findings == []


# ---------------------------------------------------------------------------
# SCOPE106 — manual_time without set_iteration_time
# ---------------------------------------------------------------------------

def test_scope106_triggers_when_time_is_never_reported(no_body_runs):
    r = reg()

    def body(state):
        while state.keep_running():
            state.deliver(1)
        state.set_items_processed(1)
    register_benchmark("silent_manual", body, scope="s",
                       registry=r).manual_time()
    assert rules_of(lint(r)) == ["SCOPE106"]


# ---------------------------------------------------------------------------
# SCOPE107 — hardcoded kernel block sizes bypass the tuned defaults
# ---------------------------------------------------------------------------

def test_scope107_triggers_on_literal_block_knob(no_body_runs):
    r = reg()

    def setup(params):
        from repro.kernels.matmul import matmul
        x = jnp.ones((params.n, params.n))
        return (lambda x: matmul(x, x, bm=128, bk=64)), x

    def body(state):
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_items_processed(1)
    b = register_benchmark("pinned_blocks", body, scope="s", registry=r)
    b.param_space(n=[256]).set_fixture(setup)
    found = [f for f in lint(r).findings if f.rule == "SCOPE107"]
    assert len(found) == 2 and found[0].severity == "warning"
    assert "bm=128" in found[0].message
    assert "tune" in found[0].message


def histogram_like(x, *, chunk):
    return (x, chunk)


def test_scope107_clean_when_blocks_come_from_tuning(no_body_runs):
    r = reg()

    def setup(params):
        from repro.kernels.matmul import matmul
        x = jnp.ones((params.n, params.n))
        # no literal knobs: the tuned defaults apply; non-knob kwargs
        # and non-kernel calls with a `chunk=` kwarg stay exempt
        unrelated = histogram_like(x, chunk=4096)
        return (lambda x: matmul(x, x)), x, unrelated

    def body(state):
        fn, x, _ = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_items_processed(1)
    b = register_benchmark("tuned_blocks", body, scope="s", registry=r)
    b.param_space(n=[256]).set_fixture(setup)
    assert [f for f in lint(r).findings if f.rule == "SCOPE107"] == []


# ---------------------------------------------------------------------------
# SCOPE201 — workload optimized away (the DoNotOptimize class of bugs)
# ---------------------------------------------------------------------------

def _trace_findings(registry):
    return run_lint(registry.all(), compile_checks=True).findings


def test_undelivered_constant_output_flagged_as_dce_hazard(no_body_runs):
    """A jitted fn whose result never depends on its operands is
    constant-folded by XLA; the optimized-HLO diff must flag it."""
    r = reg()

    def setup(params):
        return jax.jit(lambda x: jnp.sum(jnp.ones(4))), jnp.ones(params.n)

    def body(state):
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_items_processed(state.params.n)
    b = register_benchmark("folded", body, scope="s", registry=r)
    b.param_space(ParamSpace.product(n=[4]))
    b.set_fixture(setup)
    found = [f for f in _trace_findings(r) if f.rule == "SCOPE201"]
    assert len(found) == 1
    assert found[0].severity == "error"


def test_scope201_clean_on_real_compute(no_body_runs):
    r = reg()

    def setup(params):
        return jax.jit(lambda x: x * 2.0 + 1.0), jnp.ones(params.n)

    def body(state):
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
        state.set_items_processed(state.params.n)
    b = register_benchmark("computes", body, scope="s", registry=r)
    b.param_space(ParamSpace.product(n=[4]))
    b.set_fixture(setup)
    assert _trace_findings(r) == []


# ---------------------------------------------------------------------------
# SCOPE202 — dead operands
# ---------------------------------------------------------------------------

def test_scope202_triggers_on_unconsumed_operand(no_body_runs):
    r = reg()

    def setup(params):
        return (jax.jit(lambda x, y: x * 2.0),
                jnp.ones(params.n), jnp.ones(params.n))

    def body(state):
        fn, x, y = state.fixture
        while state.keep_running():
            state.deliver(fn(x, y))
        state.set_items_processed(state.params.n)
    b = register_benchmark("deadop", body, scope="s", registry=r)
    b.param_space(ParamSpace.product(n=[4]))
    b.set_fixture(setup)
    found = [f for f in _trace_findings(r) if f.rule == "SCOPE202"]
    assert len(found) == 1 and "2 operand leaves" in found[0].message


# ---------------------------------------------------------------------------
# SCOPE203 — opaque fixture convention
# ---------------------------------------------------------------------------

def test_scope203_triggers_on_nonconforming_fixture(no_body_runs):
    r = reg()

    def setup(params):
        return np.ones(params.n), np.ones(params.n)

    def body(state):
        x, y = state.fixture
        while state.keep_running():
            state.deliver(x + y)
        state.set_items_processed(state.params.n)
    b = register_benchmark("opaque", body, scope="s", registry=r)
    b.param_space(ParamSpace.product(n=[4]))
    b.set_fixture(setup)
    found = [f for f in _trace_findings(r) if f.rule == "SCOPE203"]
    assert len(found) == 1 and found[0].severity == "info"


def test_trace_rules_skipped_without_compile_checks(no_body_runs):
    r = reg()
    report = lint(r)
    assert "SCOPE201" not in report.rules_run
    assert "SCOPE101" in report.rules_run


# ---------------------------------------------------------------------------
# SCOPE301 — duplicate points after dead-axis projection
# ---------------------------------------------------------------------------

def test_scope301_triggers_on_projected_duplicates(no_body_runs):
    r = reg()

    def body(state):
        n = state.params.n
        while state.keep_running():
            state.deliver(n)
        state.set_items_processed(n)
    b = register_benchmark("dupes", body, scope="s", registry=r)
    b.param_space(ParamSpace.product(trial=[1, 2], n=[4]))
    found = [f for f in lint(r).findings if f.rule == "SCOPE301"]
    assert len(found) == 1
    assert "s/dupes/trial:1/n:4" in found[0].message
    assert "s/dupes/trial:2/n:4" in found[0].message


# ---------------------------------------------------------------------------
# SCOPE302 — instance-name collisions across families
# ---------------------------------------------------------------------------

def test_scope302_triggers_on_instance_name_collision(no_body_runs):
    r = reg()

    def swept(state):
        n = state.range(0)
        while state.keep_running():
            state.deliver(n)
        state.set_items_processed(n)
    b = register_benchmark("x", swept, scope="s", registry=r)
    b.args([4]).set_arg_names(["n"])

    def fixed(state):
        while state.keep_running():
            state.deliver(4)
        state.set_items_processed(4)
    register_benchmark("x/n:4", fixed, scope="s", registry=r)
    found = [f for f in lint(r).findings if f.rule == "SCOPE302"]
    assert len(found) == 1 and "'s/x/n:4'" in found[0].message
    assert found[0].severity == "error"


# ---------------------------------------------------------------------------
# SCOPE303 — empty sweeps and empty scopes
# ---------------------------------------------------------------------------

def test_scope303_triggers_on_zero_instances_and_empty_scope(no_body_runs):
    r = reg()

    def body(state):
        n = state.params.n
        while state.keep_running():
            state.deliver(n)
        state.set_items_processed(n)
    b = register_benchmark("empty", body, scope="s", registry=r)
    b.param_space(ParamSpace.product(n=[]))
    report = run_lint(r.all(), scope_names=["s", "ghost"],
                      compile_checks=False)
    found = [f for f in report.findings if f.rule == "SCOPE303"]
    assert {f.target() for f in found} == {"s/empty", "ghost"}


# ---------------------------------------------------------------------------
# framework: registration, selection, reporting, isolation
# ---------------------------------------------------------------------------

def test_register_rule_validates_and_rejects_duplicates():
    with pytest.raises(ValueError, match="no id"):
        register_rule(type("R", (Rule,), {}))
    with pytest.raises(ValueError, match="severity"):
        register_rule(type("R", (Rule,), {"id": "X1", "severity": "fatal"}))
    with pytest.raises(ValueError, match="already registered"):
        register_rule(type("R", (Rule,), {"id": "SCOPE101",
                                          "severity": "error"}))


def test_parse_rules_validates_ids():
    assert parse_rules("SCOPE101, SCOPE201,SCOPE101") == \
        ["SCOPE101", "SCOPE201"]
    with pytest.raises(ValueError, match="unknown rule"):
        parse_rules("SCOPE101,NOPE")
    with pytest.raises(ValueError, match="at least one"):
        parse_rules(" , ")


def test_custom_rule_registration_and_selection(no_body_runs):
    @register_rule
    class TooManyInstances(FamilyRule):
        id = "TST901"
        severity = "warning"
        title = "family sweeps more than 2 instances"
        fix_hint = "prune the space"

        def check_family(self, ctx, fam):
            if len(fam.bench.instances()) > 2:
                yield self.finding(fam)
    try:
        r = reg()

        def body(state):
            n = state.params.n
            while state.keep_running():
                state.deliver(n)
            state.set_items_processed(n)
        b = register_benchmark("wide", body, scope="s", registry=r)
        b.param_space(ParamSpace.product(n=[1, 2, 4]))
        report = run_lint(r.all(), rules=["TST901"], compile_checks=False)
        assert report.rules_run == ["TST901"]
        assert rules_of(report) == ["TST901"]
        assert report.findings[0].fix_hint == "prune the space"
    finally:
        RULES.pop("TST901")


def test_crashing_rule_does_not_kill_the_pass(no_body_runs):
    @register_rule
    class Broken(FamilyRule):
        id = "TST902"
        severity = "error"
        title = "always crashes"

        def check_family(self, ctx, fam):
            raise RuntimeError("boom")
    try:
        r = reg()

        def body(state):
            while state.keep_running():
                pass
        register_benchmark("buggy", body, scope="s", registry=r)
        report = run_lint(r.all(), compile_checks=False)
        assert "TST902" in report.rules_run
        assert "SCOPE101" in rules_of(report)   # others still reported
    finally:
        RULES.pop("TST902")


def test_report_gate_counts_and_json():
    report = LintReport(findings=[], families_checked=3, scopes_checked=1,
                        rules_run=["SCOPE101"])
    assert not report.failed() and not report.failed(strict=True)
    from repro.core.lint import Finding
    warn = Finding(rule="W", severity="warning", scope="s", family="s/f",
                   message="m")
    err = Finding(rule="E", severity="error", scope="s", family="s/f",
                  message="m", fix_hint="h", location="f.py:3")
    report.findings.append(warn)
    assert not report.failed() and report.failed(strict=True)
    report.findings.append(err)
    assert report.failed()
    doc = report.to_json()
    assert doc["version"] == 1 and doc["counts"] == \
        {"error": 1, "warning": 1, "info": 0}
    assert doc["findings"][1]["location"] == "f.py:3"
    text = report.format_text()
    assert text.index("E error") < text.index("W warning")
    assert "fix: h" in text


def test_findings_carry_registration_location(no_body_runs):
    r = reg()

    def body(state):
        while state.keep_running():
            pass
    register_benchmark("located", body, scope="s", registry=r)
    f = [f for f in lint(r).findings if f.rule == "SCOPE101"][0]
    assert f.location.startswith(__file__.replace(".pyc", ".py"))


# ---------------------------------------------------------------------------
# the ten builtin scopes lint clean — without executing anything
# ---------------------------------------------------------------------------

def test_builtin_scopes_lint_clean(no_body_runs):
    mgr = ScopeManager(registry=BenchmarkRegistry(), flags=FlagRegistry(),
                       hooks=HookChain())
    mgr.load(BUILTIN_SCOPES)
    mgr.register_all()
    benches = mgr.registry.all()
    assert len(benches) >= 20
    report = run_lint(benches, scope_names=sorted(mgr.status()),
                      compile_checks=False)
    assert report.scopes_checked == 10
    assert not report.failed(strict=True), report.format_text()


def test_linalg_scope_compile_tier_clean(no_body_runs):
    """Full pass (AST + trace + registry) over one jax scope: fixtures
    are built and lowered, bodies still never run."""
    mgr = ScopeManager(registry=BenchmarkRegistry(), flags=FlagRegistry(),
                       hooks=HookChain())
    mgr.load(["repro.scopes.linalg_scope"])
    mgr.register_all()
    report = run_lint(mgr.registry.all(), compile_checks=True)
    assert "SCOPE201" in report.rules_run
    assert not report.failed(strict=True), report.format_text()
    assert report.counts()["info"] == 0


# ---------------------------------------------------------------------------
# CLI end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture
def global_state():
    """Snapshot/restore the process-global FLAGS/HOOKS/REGISTRY that
    lint_main drives, so CLI tests don't leak into other tests."""
    from repro.core.flags import FLAGS
    from repro.core.hooks import HOOKS
    from repro.core.registry import REGISTRY
    specs, values = dict(FLAGS._specs), dict(FLAGS._values)
    pre, post = list(HOOKS._pre_parse), list(HOOKS._post_parse)
    benches = dict(REGISTRY._benchmarks)
    yield
    FLAGS._specs.clear(), FLAGS._specs.update(specs)
    FLAGS._values.clear(), FLAGS._values.update(values)
    HOOKS._pre_parse[:], HOOKS._post_parse[:] = pre, post
    REGISTRY._benchmarks.clear(), REGISTRY._benchmarks.update(benches)


def _fake_scope_module(name, register):
    modname = f"fake_lint_scopes.{name}"
    mod = types.ModuleType(modname)
    mod.SCOPE = Scope(name=name, register=register)
    sys.modules[modname] = mod
    return modname


def cli(args, modules, entry=None):
    """One lint_main/main call against a pristine global registry (the
    process-global REGISTRY would otherwise accumulate registrations
    across calls and collide)."""
    from repro.core.registry import REGISTRY
    saved = dict(REGISTRY._benchmarks)
    REGISTRY._benchmarks.clear()
    try:
        return (entry or lint_main)(args, modules)
    finally:
        REGISTRY._benchmarks.clear()
        REGISTRY._benchmarks.update(saved)


@pytest.fixture
def buggy_scope(global_state):
    def _register(registry):
        def unfenced(state):
            while state.keep_running():
                pass
            state.set_items_processed(1)
        register_benchmark("unfenced", unfenced, scope="buggy",
                           registry=registry)
    name = _fake_scope_module("buggy", _register)
    yield name
    sys.modules.pop(name)


@pytest.fixture
def warn_scope(global_state):
    def _register(registry):
        def bare(state):
            while state.keep_running():
                state.deliver(1)
        _quietly(register_benchmark("bare", bare, scope="warny",
                                    registry=registry))
    name = _fake_scope_module("warny", _register)
    yield name
    sys.modules.pop(name)


@pytest.fixture
def clean_scope(global_state):
    def _register(registry):
        def good(state):
            while state.keep_running():
                state.deliver(1)
            state.set_items_processed(1)
        _quietly(register_benchmark("good", good, scope="cleany",
                                    registry=registry))
    name = _fake_scope_module("cleany", _register)
    yield name
    sys.modules.pop(name)


def test_cli_exit_codes(no_body_runs, capsys, buggy_scope, warn_scope,
                        clean_scope):
    # errors gate with and without --strict
    assert cli(["--no-compile"], [buggy_scope]) == 1
    out = capsys.readouterr().out
    assert "SCOPE101" in out and "1 error(s)" in out
    # warnings gate only under --strict
    assert cli(["--no-compile"], [warn_scope]) == 0
    capsys.readouterr()
    assert cli(["--no-compile", "--strict"], [warn_scope]) == 1
    assert "SCOPE104" in capsys.readouterr().out
    # a clean scope passes even strict
    assert cli(["--no-compile", "--strict"], [clean_scope]) == 0


def test_cli_scope_and_family_selection(no_body_runs, capsys, buggy_scope,
                                        clean_scope):
    # --scope narrows to the clean scope: the buggy one never gates
    assert cli(["--no-compile", "--scope", "cleany"],
               [buggy_scope, clean_scope]) == 0
    capsys.readouterr()
    # --family regex selecting nothing is a usage error
    assert cli(["--no-compile", "--family", "nope$"], [clean_scope]) == 2
    # --family narrows within a scope (and doesn't make the unselected
    # buggy scope look empty to SCOPE303)
    assert cli(["--no-compile", "--strict", "--family", "cleany/good"],
               [buggy_scope, clean_scope]) == 0


def test_cli_json_contract(no_body_runs, capsys, buggy_scope):
    assert cli(["--no-compile", "--format", "json"], [buggy_scope]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["version"] == 1 and doc["failed"] is True
    assert doc["counts"]["error"] == 1
    (finding,) = doc["findings"]
    assert finding["rule"] == "SCOPE101"
    assert finding["family"] == "buggy/unfenced"
    assert finding["fix_hint"]


def test_cli_rules_subset_and_list(no_body_runs, capsys, buggy_scope):
    assert cli(["--no-compile", "--rules", "SCOPE104"],
               [buggy_scope]) == 0          # 101 not selected
    capsys.readouterr()
    assert cli(["--rules", "BOGUS"], [buggy_scope]) == 2
    assert cli(["--list-rules"], [buggy_scope]) == 0
    out = capsys.readouterr().out
    for rule_id in RULES:
        assert rule_id in out


def test_main_dispatches_lint_and_help(no_body_runs, capsys, clean_scope):
    from repro.core.main import main
    assert cli(["lint", "--no-compile"], [clean_scope], entry=main) == 0
    capsys.readouterr()
    assert cli(["lint", "--help"], [clean_scope], entry=main) == 0
    out = capsys.readouterr().out
    assert "--strict" in out and "python -m repro lint" in out


def test_run_lint_preflight_aborts_before_running(no_body_runs, capsys,
                                                  buggy_scope):
    from repro.core.main import main
    assert cli(["run", "--lint", "--results-dir", ""],
               [buggy_scope], entry=main) == 1
    err = capsys.readouterr().err
    assert "SCOPE101" in err


def test_analysis_handles_for_loop_and_nested_loops(no_body_runs):
    r = reg()

    def body(state):
        for _ in state:
            state.deliver(np.ones(4))
        state.set_items_processed(4)
    register_benchmark("forloop", body, scope="s", registry=r)
    found = [f for f in lint(r).findings if f.rule == "SCOPE102"]
    assert len(found) == 1           # np.ones inside `for _ in state`

    b = r.get("s/forloop")
    ana = FamilyAnalysis(b)
    assert len(ana.timed_loops) == 1


# ---------------------------------------------------------------------------
# SCOPE108 — meters reading host clocks
# ---------------------------------------------------------------------------

def _clean_family(r):
    def body(state):
        while state.keep_running():
            state.deliver(1)
        state.set_items_processed(1)
    register_benchmark("f", body, scope="s", registry=r)


def test_scope108_triggers_on_clock_reading_meter(no_body_runs, monkeypatch):
    import time

    from repro.core.measure import METERS, Meter

    class StampsItself(Meter):
        name = "stampsitself"

        def begin(self, state):
            self._t0 = time.perf_counter()

        def end(self, state):
            return {"elapsed": time.perf_counter() - self._t0}

    monkeypatch.setitem(METERS, "stampsitself", StampsItself)
    r = reg()
    _clean_family(r)
    found = [f for f in lint(r).findings if f.rule == "SCOPE108"]
    assert found
    assert all(f.family == "meter:stampsitself" for f in found)
    assert all(f.severity == "error" for f in found)
    assert {m for f in found for m in ("begin", "end")
            if f"StampsItself.{m}" in f.message} == {"begin", "end"}


def test_scope108_flags_the_observe_channel(no_body_runs, monkeypatch):
    """observe() is the per-sample path — a self-read clock there stamps
    enqueue time per request, the exact bug class fence_timestamps
    exists for."""
    import time

    from repro.core.measure import METERS, Meter

    class ObserveStamper(Meter):
        name = "observestamper"

        def observe(self, state, sample):
            sample = dict(sample)
            sample["seen_at"] = time.time()

    monkeypatch.setitem(METERS, "observestamper", ObserveStamper)
    r = reg()
    _clean_family(r)
    found = [f for f in lint(r).findings if f.rule == "SCOPE108"]
    assert len(found) == 1
    assert "ObserveStamper.observe" in found[0].message
    assert "time.time" in found[0].message


def test_scope108_builtin_meters_are_clean(no_body_runs):
    r = reg()
    _clean_family(r)
    assert "SCOPE108" not in rules_of(lint(r))


# ---------------------------------------------------------------------------
# SCOPE109 — direct open() of history.jsonl outside the store layer
# ---------------------------------------------------------------------------

def test_scope109_triggers_on_direct_history_open(no_body_runs, tmp_path,
                                                  monkeypatch):
    import repro
    pkg = tmp_path / "fakepkg"
    (pkg / "store").mkdir(parents=True)
    (pkg / "core").mkdir()
    (pkg / "__init__.py").write_text("")
    # violation: a random module hand-opens the history file
    (pkg / "rogue.py").write_text(
        'import os\n'
        'def peek(d):\n'
        '    with open(os.path.join(d, "history.jsonl")) as f:\n'
        '        return f.read()\n')
    # sanctioned: the store layer and core/history.py may open it
    (pkg / "store" / "index.py").write_text(
        'def ok():\n    return open("results/history.jsonl")\n')
    (pkg / "core" / "history.py").write_text(
        'def ok():\n    return open("results/history.jsonl")\n')
    # opening some *other* file is nobody's business
    (pkg / "fine.py").write_text(
        'def ok():\n    return open("notes.txt")\n')
    monkeypatch.setattr(repro, "__file__", str(pkg / "__init__.py"))
    r = reg()
    _clean_family(r)
    found = [f for f in lint(r, rules=["SCOPE109"]).findings
             if f.rule == "SCOPE109"]
    assert len(found) == 1
    assert found[0].severity == "warning"
    assert found[0].family == "module:repro/rogue.py"
    assert "history.jsonl" in found[0].message
    assert str(pkg / "rogue.py") in found[0].location


def test_scope109_real_tree_is_clean(no_body_runs):
    """The shipped package must satisfy its own rule: only
    repro.core.history / repro.store touch the JSONL directly."""
    r = reg()
    _clean_family(r)
    report = lint(r, rules=["SCOPE109"])
    assert report.findings == []
    assert report.rules_run == ["SCOPE109"]


# ---------------------------------------------------------------------------
# SCOPE110 — body reads module-level mutable state (fingerprint-invisible)
# ---------------------------------------------------------------------------

_TABLE = {"scale": 2.0}          # the hazard: mutable, module-level
_FACTORS = [1, 2, 4]
_FROZEN = (1, 2, 4)              # immutable → clean


def test_scope110_triggers_on_module_dict_read(no_body_runs):
    r = reg()

    def body(state):
        while state.keep_running():
            state.deliver(_TABLE["scale"])
        state.set_items_processed(1)
    register_benchmark("tabled", body, scope="s", registry=r)
    found = [f for f in lint(r, rules=["SCOPE110"]).findings
             if f.rule == "SCOPE110"]
    assert len(found) == 1
    assert found[0].severity == "warning"
    assert "_TABLE" in found[0].message
    assert "dict" in found[0].message


def test_scope110_triggers_on_global_statement_and_list(no_body_runs):
    r = reg()

    def body(state):
        global _TABLE
        while state.keep_running():
            state.deliver(_FACTORS[0])
        state.set_items_processed(1)
    register_benchmark("globaled", body, scope="s", registry=r)
    msgs = [f.message for f in lint(r, rules=["SCOPE110"]).findings
            if f.rule == "SCOPE110"]
    assert len(msgs) == 2
    assert any("global _TABLE" in m for m in msgs)
    assert any("_FACTORS" in m and "list" in m for m in msgs)


def test_scope110_clean_on_locals_constants_and_modules(no_body_runs):
    r = reg()

    def body(state):
        table = {"scale": 2.0}              # local dict: fine
        acc = jnp.zeros(())                 # module read: fine
        while state.keep_running():
            state.deliver(acc + table["scale"] * _FROZEN[0])
        state.set_items_processed(1)
    _quietly(register_benchmark("selfcontained", body, scope="s",
                                registry=r))
    assert [f for f in lint(r, rules=["SCOPE110"]).findings
            if f.rule == "SCOPE110"] == []
