"""repro.core.fingerprint: instance fingerprints are deterministic
across processes, change when — and only when — a digest input changes,
and drive delta planning (`--since`) + freshness coverage correctly."""
import json
import os
import subprocess
import sys
import types

from repro.core import fingerprint as fp
from repro.core.flags import FlagRegistry
from repro.core.hooks import HookChain
from repro.core.registry import BenchmarkRegistry
from repro.core.scope import ScopeManager

EXAMPLE = ["repro.scopes.example_scope"]


def make_mgr(modules):
    mgr = ScopeManager(registry=BenchmarkRegistry(), flags=FlagRegistry(),
                       hooks=HookChain())
    mgr.load(modules)
    mgr.register_all()
    return mgr


def example_benches():
    return make_mgr(EXAMPLE).registry.all()


def rec(name, fingerprint, *, run_id="r1", ts="2026-08-01T00:00:00",
        sysinfo="m1", mean=1.0, **extra):
    out = {"run_id": run_id, "ts": ts, "name": name, "mean_s": mean,
           "stddev_s": 0.0, "n": 1, "errors": 0, "sysinfo": sysinfo,
           "verdict": "new", "fingerprint": fingerprint}
    out.update(extra)
    return out


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_fingerprints_stable_within_process():
    a = fp.registry_fingerprints(example_benches())
    b = fp.registry_fingerprints(example_benches())
    assert a and a == b
    assert all(len(v) == fp.DIGEST_LEN for v in a.values())


def test_fingerprints_stable_across_processes(monkeypatch):
    """The acceptance bar: a fresh interpreter computes byte-identical
    digests (content-based inputs only — no paths, pids, or times)."""
    parent = fp.registry_fingerprints(example_benches())
    code = (
        "import json\n"
        "from repro.core.fingerprint import registry_fingerprints\n"
        "from tests.test_fingerprint import example_benches\n"
        "print(json.dumps(registry_fingerprints(example_benches())))\n"
    )
    env = dict(os.environ)   # children must inherit the env (jax probe)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert json.loads(out.stdout) == parent


def test_family_inputs_are_labeled_and_content_based():
    bench = {b.name: b for b in example_benches()}["example/axpy"]
    ins = fp.family_inputs(bench)
    assert set(ins) == {"version", "body", "fixture", "sync", "meters",
                        "tunable", "kernels", "tuned", "jax", "jaxlib"}
    assert "def axpy" in ins["body"]
    assert "def axpy_setup" in ins["fixture"]
    # nothing environment-shaped leaks into the digest inputs
    blob = json.dumps(ins)
    assert os.sep + "repo" not in blob and "/root/" not in blob


# ---------------------------------------------------------------------------
# sensitivity: each input moves the digest; nothing else does
# ---------------------------------------------------------------------------

def axpy():
    return {b.name: b for b in example_benches()}["example/axpy"]


def test_digest_changes_on_body_edit():
    a, b = axpy(), axpy()
    b.source = b.source + "  # edited\n"
    assert fp.family_digest(a) != fp.family_digest(b)


def test_digest_changes_on_fixture_edit():
    a, b = axpy(), axpy()
    b.fixture_source = b.fixture_source + "  # edited\n"
    assert fp.family_digest(a) != fp.family_digest(b)


def test_digest_changes_on_jax_version(monkeypatch):
    a = fp.family_digest(axpy())
    real = fp._stack_versions()
    monkeypatch.setattr(fp, "_stack_versions",
                        lambda: dict(real, jax="99.0.0"))
    assert fp.family_digest(axpy()) != a


def test_digest_changes_on_kernel_source(monkeypatch):
    """A family importing a Pallas kernel re-fingerprints when any
    module in the kernel's transitive closure changes."""
    bench = axpy()
    bench.source = ("def body(state):\n"
                    "    from repro.kernels.matmul import matmul\n")
    base = fp.family_digest(bench)
    real = fp._module_source
    monkeypatch.setattr(
        fp, "_module_source",
        lambda m: (real(m) or "") + "# patched\n"
        if m == "repro.kernels.matmul.kernel" else real(m))
    assert fp.family_digest(bench) != base


def test_digest_changes_on_tuned_artifact(monkeypatch, tmp_path):
    mgr = make_mgr(["repro.scopes.mxu_scope"])
    bench = {b.name: b for b in mgr.registry.all()}["mxu/matmul"]
    assert bench.tunable is not None
    base = fp.family_inputs(bench)
    (tmp_path / "matmul").mkdir()
    (tmp_path / "matmul" / "tuned.json").write_text(json.dumps(
        {"config": {"bm": 8, "bn": 8, "bk": 8}}))
    monkeypatch.setenv("REPRO_TUNED_DIR", str(tmp_path))
    from repro.kernels import tuning
    tuning.invalidate_cache()
    try:
        new = fp.family_inputs(bench)
    finally:
        monkeypatch.delenv("REPRO_TUNED_DIR")
        tuning.invalidate_cache()
    assert new["tuned"] != base["tuned"]
    assert {k for k in base if base[k] != new[k]} == {"tuned"}


def test_params_split_families_share_family_digest():
    bench = axpy()
    fam = fp.family_digest(bench)
    names = dict(bench.instances())
    fps = {name: fp.instance_fingerprint(bench, params, fam)
           for name, params in bench.instances()}
    assert len(set(fps.values())) == len(names)   # one per point
    # same params → same fingerprint, independent of family iteration
    again = {name: fp.instance_fingerprint(bench, params)
             for name, params in bench.instances()}
    assert fps == again


def test_kernel_dependencies_transitive_closure():
    src = "from repro.kernels.matmul import matmul as pallas_matmul\n"
    deps = fp.kernel_dependencies([src])
    assert "repro.kernels.matmul" in deps
    assert "repro.kernels.matmul.kernel" in deps    # via ops/__init__
    assert "repro.kernels.tuning" in deps
    assert all(d.startswith("repro.kernels") for d in deps)
    # indented source (fixture bodies) parses the same
    assert fp.kernel_dependencies(["    " + src]) == deps
    assert fp.kernel_dependencies(["import numpy as np\n"]) == []


# ---------------------------------------------------------------------------
# freshness classification + delta split
# ---------------------------------------------------------------------------

def test_classify_states():
    assert fp.classify("aa", None) == fp.NEVER
    assert fp.classify("aa", rec("x", "bb")) == fp.STALE
    assert fp.classify("aa", rec("x", "aa")) == fp.FRESH
    assert fp.classify("aa", rec("x", "aa", mean=None)) == fp.STALE
    assert fp.classify("aa", rec("x", "aa", errors=1)) == fp.STALE
    assert fp.classify("aa", rec("x", "aa", ts="2026-07-01T00:00:00"),
                       since="2026-08-01") == fp.STALE
    assert fp.classify("aa", rec("x", "aa", ts="2026-08-02T00:00:00"),
                       since="2026-08-01") == fp.FRESH


def test_latest_measurements_skips_cached_tune_and_other_machines():
    records = [
        rec("s/a", "f1", run_id="r1"),
        rec("s/a", "f2", run_id="r2", cached=True),     # replay: no vouch
        rec("s/b", "f3", run_id="r2", tag="tune"),      # trial: no vouch
        rec("s/c", "f4", run_id="r2", sysinfo="m2"),    # other machine
    ]
    latest = fp.latest_measurements(records, sysinfo="m1")
    assert set(latest) == {"s/a"}
    assert latest["s/a"]["fingerprint"] == "f1"


def test_delta_split_prunes_only_fresh():
    items = [types.SimpleNamespace(instance_id=f"i{i}", name=n)
             for i, n in enumerate(["s/a", "s/b", "s/c"])]
    fps = {"s/a": "fa", "s/b": "fb", "s/c": "fc"}
    records = [rec("s/a", "fa"),            # fresh → cached
               rec("s/b", "old")]           # stale → runs
    pending, cached = fp.delta_split(items, fps, records, "m1")
    assert [i.name for i in pending] == ["s/b", "s/c"]
    assert set(cached) == {"i0"}
    assert cached["i0"]["fingerprint"] == "fa"


def test_coverage_counts_per_scope():
    benches = example_benches()
    cov = fp.coverage(benches, [])
    n = cov["instances"]
    assert n > 0 and cov["totals"] == {"fresh": 0, "stale": 0, "never": n}
    # forge fresh records for every instance on machine m1
    fps = fp.registry_fingerprints(benches)
    records = [rec(name, digest) for name, digest in fps.items()]
    cov = fp.coverage(benches, records, sysinfo="m1")
    assert cov["totals"] == {"fresh": n, "stale": 0, "never": 0}
    assert cov["pending"] == []
    # one stale fingerprint shows up as pending again
    records[0]["fingerprint"] = "stale"
    cov = fp.coverage(benches, records, sysinfo="m1")
    assert cov["totals"]["stale"] == 1
    assert cov["pending"] == [records[0]["name"]]
