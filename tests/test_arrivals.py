"""repro.core.arrivals: seeded open-loop traffic generators — shape,
validation, and the byte-identical-replay determinism contract."""
import os
import subprocess
import sys

import pytest

from repro.core.arrivals import (ARRIVAL_KINDS, bursty, diurnal, generate,
                                 poisson)


def _is_sorted(xs):
    return all(a <= b for a, b in zip(xs, xs[1:]))


@pytest.mark.parametrize("kind", ARRIVAL_KINDS)
def test_generators_produce_sorted_positive_offsets(kind):
    offs = generate(kind, rate=20.0, n=50, seed=3)
    assert len(offs) == 50
    assert _is_sorted(offs)
    assert all(t > 0.0 for t in offs)


@pytest.mark.parametrize("kind", ARRIVAL_KINDS)
def test_same_seed_replays_byte_identical(kind):
    a = generate(kind, rate=8.0, n=40, seed=7)
    b = generate(kind, rate=8.0, n=40, seed=7)
    assert a == b                              # identical floats, not close
    assert generate(kind, rate=8.0, n=40, seed=8) != a


def test_poisson_mean_rate_converges():
    offs = poisson(rate=50.0, n=5000, seed=0)
    mean_gap = offs[-1] / len(offs)
    assert mean_gap == pytest.approx(1.0 / 50.0, rel=0.1)


def test_bursty_has_more_variance_than_poisson():
    """The whole point of the on/off process: same mean-ish rate, much
    burstier inter-arrival distribution."""
    import statistics
    p = poisson(rate=40.0, n=2000, seed=1)
    b = bursty(rate=40.0, n=2000, seed=1)
    gaps = lambda xs: [y - x for x, y in zip(xs, xs[1:])]  # noqa: E731
    assert statistics.pvariance(gaps(b)) > statistics.pvariance(gaps(p))


def test_diurnal_ramps_density_with_period():
    """Arrivals cluster mid-period (rate peak) vs the window edges."""
    offs = diurnal(rate=200.0, n=400, seed=2, period=2.0, floor=0.05)
    horizon = offs[-1]
    mid = sum(1 for t in offs if 0.5 <= (t % 2.0) < 1.5)
    edge = sum(1 for t in offs if (t % 2.0) < 0.5 or (t % 2.0) >= 1.5)
    assert horizon > 0
    assert mid > edge


def test_validation_errors():
    with pytest.raises(ValueError, match="rate"):
        poisson(rate=0.0, n=5)
    with pytest.raises(ValueError, match="count"):
        poisson(rate=1.0, n=-1)
    with pytest.raises(ValueError, match="burst_factor"):
        bursty(rate=1.0, n=5, burst_factor=0.0)
    with pytest.raises(ValueError, match="floor"):
        diurnal(rate=1.0, n=5, floor=0.0)
    with pytest.raises(ValueError, match="available"):
        generate("uniform", rate=1.0, n=5)


def test_generate_zero_requests_is_empty():
    assert generate("poisson", rate=5.0, n=0) == []


def test_trace_replays_byte_identical_across_processes():
    """The determinism contract shard workers rely on: a fresh
    interpreter reproduces the exact same floats for (kind, rate, n,
    seed).  The module is jax-free, so the subprocess import is cheap."""
    local = repr([generate(k, 16.0, 10, seed=5) for k in ARRIVAL_KINDS])
    code = ("from repro.core.arrivals import ARRIVAL_KINDS, generate;"
            "print(repr([generate(k, 16.0, 10, seed=5)"
            " for k in ARRIVAL_KINDS]))")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath("src")] + ([env["PYTHONPATH"]]
                                    if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, check=True)
    assert out.stdout.strip() == local
