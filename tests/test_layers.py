"""Layer-level correctness: flash-vs-naive, SSD, MoE, conv, loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models import layers as L


@pytest.mark.parametrize("S,H,K,D,cq,ck", [
    (64, 4, 4, 16, 16, 16),
    (128, 4, 2, 32, 32, 64),
    (96, 6, 2, 16, 32, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_naive(S, H, K, D, cq, ck, causal):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, S, K, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, S, K, D))
    ref = L.naive_attention(q, k, v, causal=causal)
    out = L.flash_attention_xla(q, k, v, causal=causal, chunk_q=cq,
                                chunk_k=ck)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_grads_match_naive():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v) ** 2)

    gf = jax.grad(loss(lambda q, k, v: L.flash_attention_xla(
        q, k, v, chunk_q=16, chunk_k=16)), argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss(lambda q, k, v: L.naive_attention(q, k, v)),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@given(st.integers(1, 4), st.integers(8, 48), st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_ssd_chunked_equals_reference(b, l, h):
    """SSD duality: chunked == sequential recurrence (property)."""
    p, n = 8, 8
    key = jax.random.PRNGKey(l * 7 + b)
    x = jax.random.normal(key, (b, l, h, p)) * 0.4
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (b, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(3), (b, l, 1, n)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(4), (b, l, 1, n)) * 0.3
    D = jnp.ones((h,))
    y1, s1 = L.ssd_reference(x, dt, A, Bm, Cm, D)
    y2, s2 = L.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=3e-5)


def test_moe_scatter_equals_einsum():
    p = L.init_moe(jax.random.PRNGKey(0), 32, 8, 64, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    ys, auxs = L.moe_scatter(p, x, top_k=2, capacity_factor=8.0, n_shared=1)
    ye, auxe = L.moe_einsum(p, x, top_k=2, capacity_factor=8.0, n_shared=1)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ye), atol=1e-5)
    assert abs(float(auxs - auxe)) < 1e-6


def test_moe_capacity_drops_tokens():
    """With tiny capacity, outputs differ from infinite capacity (drops)."""
    p = L.init_moe(jax.random.PRNGKey(0), 16, 4, 32, 0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    y_small, _ = L.moe_scatter(p, x, top_k=2, capacity_factor=0.25)
    y_big, _ = L.moe_scatter(p, x, top_k=2, capacity_factor=8.0)
    assert np.abs(np.asarray(y_small - y_big)).max() > 1e-4


@given(st.integers(1, 512), st.integers(1, 64), st.integers(1, 8),
       st.floats(0.5, 4.0))
@settings(max_examples=40, deadline=None)
def test_moe_capacity_invariants(T, E, k, cf):
    C = L.moe_capacity(T, E, k, cf)
    assert C >= 8 and C % 8 == 0
    assert C >= min(8, int(np.ceil(T * k / E * cf)))


def test_causal_conv_matches_decode_path():
    """Streaming conv (decode) == full conv applied position-wise."""
    k, C = 4, 6
    w = jax.random.normal(jax.random.PRNGKey(0), (k, C)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, C))
    full = L.causal_conv1d(w, x)
    tail = jnp.zeros((2, k - 1, C))
    outs = []
    for t in range(10):
        out, tail = L._conv_decode(w, tail, x[:, t:t + 1])
        outs.append(out)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stream),
                               atol=1e-5)


def test_chunked_loss_matches_unchunked():
    table = jax.random.normal(jax.random.PRNGKey(0), (64, 16)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 64)
    full = L.chunked_loss(table, x, labels, 0, jnp.float32)
    chunked = L.chunked_loss(table, x, labels, 8, jnp.float32)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-6)


def test_rope_relative_property():
    """RoPE: scores depend only on relative positions."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 1, 32))
    def scores(offset):
        pos = jnp.arange(4)[None] + offset
        qr = L.apply_rope(q, pos, 1e4)
        kr = L.apply_rope(k, pos, 1e4)
        return jnp.einsum("bqhd,bshd->bqs", qr, kr)
    np.testing.assert_allclose(np.asarray(scores(0)),
                               np.asarray(scores(100)), atol=1e-3)


def test_pick_chunk_divides():
    for S in (1500, 4096, 51865, 7):
        c = L.pick_chunk(S, 512)
        assert S % c == 0 and c <= 512
