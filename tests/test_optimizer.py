"""AdamW + schedule + clipping reference checks."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.train import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, warmup_cosine)


def test_adamw_first_step_matches_reference():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8,
                      weight_decay=0.0, warmup_steps=0, total_steps=10,
                      min_lr_ratio=1.0)
    params = {"w": jnp.asarray([[1.0, 2.0]])}
    grads = {"w": jnp.asarray([[0.1, -0.2]])}
    opt = adamw_init(params)
    new_p, new_opt, lr = adamw_update(cfg, grads, opt, params)
    # bias-corrected first step == lr * sign-ish update
    g = np.asarray([[0.1, -0.2]])
    m_hat = g
    v_hat = g ** 2
    expect = np.asarray([[1.0, 2.0]]) - 1e-2 * m_hat / (np.sqrt(v_hat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(new_opt["count"]) == 1


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=1.0, weight_decay=0.5, warmup_steps=0,
                      total_steps=1, min_lr_ratio=1.0)
    params = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
    grads = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(cfg, grads, adamw_init(params), params)
    assert np.all(np.asarray(new_p["w"]) < 1.0)       # decayed
    np.testing.assert_allclose(np.asarray(new_p["scale"]), 1.0)


@given(st.floats(0.1, 10.0))
@settings(max_examples=20, deadline=None)
def test_clip_bound(max_norm):
    grads = {"a": jnp.full((8,), 3.0), "b": jnp.full((4,), -2.0)}
    clipped, gnorm = clip_by_global_norm(grads, max_norm)
    total = np.sqrt(sum(np.sum(np.square(np.asarray(g)))
                        for g in jax.tree.leaves(clipped)))
    assert total <= max_norm * 1.001 + 1e-6
    assert float(gnorm) > 0


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    sched = warmup_cosine(cfg)
    assert float(sched(jnp.asarray(0))) < 0.15
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 0.01
    assert float(sched(jnp.asarray(100))) <= 0.11
