"""Straggler watchdog + data reassignment."""
import numpy as np

from repro.distributed.straggler import (DataReassigner, StragglerConfig,
                                         StragglerWatchdog)


def test_detects_persistent_straggler():
    wd = StragglerWatchdog(4, StragglerConfig(threshold=1.5, patience=3))
    flagged = []
    for _ in range(10):
        times = np.asarray([1.0, 1.0, 1.0, 3.0])
        flagged += wd.record_step(times)
    assert flagged == [3]
    assert wd.flagged == [3]


def test_transient_spike_not_flagged():
    wd = StragglerWatchdog(4, StragglerConfig(threshold=1.5, patience=3))
    for i in range(10):
        times = np.asarray([1.0, 1.0, 1.0, 4.0 if i == 5 else 1.0])
        assert wd.record_step(times) == []


def test_reassigner_offsets_complete_and_monotonic():
    ra = DataReassigner(global_batch=64, num_hosts=4)
    ra.derate(2, 0.5)
    off = ra.offsets()
    assert off[0] == 0 and off[-1] == 64
    assert all(off[i] <= off[i + 1] for i in range(len(off) - 1))
    sizes = np.diff(off)
    assert sizes[2] < sizes[0]            # derated host gets less work
    # slices cover the batch exactly once
    covered = sum((ra.slice_for(h).stop - ra.slice_for(h).start)
                  for h in range(4))
    assert covered == 64
