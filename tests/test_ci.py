"""End-to-end continuous benchmarking: `repro run --since` delta runs
replay fresh instances as cached while keeping documents complete,
editing one family re-plans exactly that family, and `repro ci` gates
(exit 1) on an injected regression."""
import importlib
import json
import os
import sys

import pytest

from repro.core import history as hist
from repro.core.ci import ci_main
from repro.core.main import plan_main, run_main
from repro.core.registry import REGISTRY

SCOPE_TEMPLATE = '''\
from repro.core import Scope, State, benchmark


def _register(registry):
    @benchmark(scope="tmpci", registry=registry)
    def alpha(state):
        """{alpha_doc}"""
        x = 0.0
        while state.keep_running():
            x = state.deliver(x + 1.0)
        state.set_items_processed(1)
    alpha.set_sync(lambda ctx: None)

    @benchmark(scope="tmpci", registry=registry)
    def beta(state):
        y = 0.0
        while state.keep_running():
            {beta_line}
        state.set_items_processed(1)
    beta.set_sync(lambda ctx: None)


SCOPE = Scope(name="tmpci", register=_register)
'''

BETA_FAST = "y = state.deliver(y + 2.0)"
BETA_SLOW = ("y = state.deliver(sum(float(i) for i in range(20000)))")

MODNAME = "tmpci_scope_mod"
FAST_FLAGS = ["--benchmark_min_time", "0.002"]


@pytest.fixture
def scope_file(tmp_path, monkeypatch):
    """A throwaway scope module the tests can rewrite + reload."""
    path = tmp_path / f"{MODNAME}.py"
    path.write_text(SCOPE_TEMPLATE.format(alpha_doc="v1",
                                          beta_line=BETA_FAST))
    monkeypatch.syspath_prepend(str(tmp_path))
    yield path
    sys.modules.pop(MODNAME, None)


def rewrite(path, alpha_doc="v1", beta_line=BETA_FAST):
    path.write_text(SCOPE_TEMPLATE.format(alpha_doc=alpha_doc,
                                          beta_line=beta_line))
    importlib.reload(sys.modules[MODNAME])


def cli(fn, argv):
    REGISTRY.reset()              # run/ci register into the global registry
    return fn(argv, scope_modules=[MODNAME])


def run_args(d, run_id, *extra):
    return ["--results-dir", d, "--run-id", run_id,
            "--shard-grain", "benchmark", *extra, *FAST_FLAGS]


def merged(d, run_id):
    with open(os.path.join(d, run_id, "merged.json")) as f:
        return json.load(f)


def split_cached(doc):
    recs = [b for b in doc["benchmarks"] if b["run_type"] == "iteration"]
    live = sorted(b["name"] for b in recs if not b.get("cached"))
    cached = sorted(b["name"] for b in recs if b.get("cached"))
    return live, cached


def test_delta_run_skips_fresh_and_stays_complete(scope_file, tmp_path,
                                                  capsys):
    d = str(tmp_path / "results")

    assert cli(run_main, run_args(d, "full")) == 0
    live, cached = split_cached(merged(d, "full"))
    assert live == ["tmpci/alpha", "tmpci/beta"] and cached == []
    records = hist.load_history(hist.history_path(d))
    assert all(len(r.get("fingerprint", "")) == 16 for r in records)

    # unchanged tree: --since plans zero instances, replays everything
    assert cli(run_main, run_args(d, "noop", "--since")) == 0
    live, cached = split_cached(merged(d, "noop"))
    assert live == [] and cached == ["tmpci/alpha", "tmpci/beta"]
    by_name = {b["name"]: b for b in merged(d, "noop")["benchmarks"]}
    assert by_name["tmpci/alpha"]["cached_from_run"] == "full"

    # the plan view agrees without running anything
    assert cli(plan_main, ["--since", "--results-dir", d]) == 0
    assert "fingerprint-fresh (--since)" in capsys.readouterr().out

    # cached replays land in history but marked, and never vouch again
    records = hist.load_history(hist.history_path(d))
    noop = [r for r in records if r["run_id"] == "noop"]
    assert len(noop) == 2 and all(r["cached"] for r in noop)

    # edit ONE family body → exactly that family re-measures
    rewrite(scope_file, alpha_doc="v2")
    assert cli(run_main, run_args(d, "delta", "--since")) == 0
    live, cached = split_cached(merged(d, "delta"))
    assert live == ["tmpci/alpha"] and cached == ["tmpci/beta"]


def test_since_requires_results_dir_and_instance_grain(scope_file):
    # an ephemeral run (--results-dir '') has no history to consult
    assert cli(run_main, ["--since", "--results-dir", "",
                          *FAST_FLAGS]) == 2
    assert cli(run_main, ["--since", "--results-dir", "x",
                          "--shard-grain", "scope", *FAST_FLAGS]) == 2


def test_since_iso_floor_re_measures_old_records(scope_file, tmp_path):
    d = str(tmp_path / "results")
    assert cli(run_main, run_args(d, "full")) == 0
    # everything is fresh for a bare --since, stale against tomorrow
    assert cli(run_main, run_args(d, "n1", "--since")) == 0
    assert split_cached(merged(d, "n1"))[0] == []
    assert cli(run_main,
               run_args(d, "n2", "--since", "2999-01-01")) == 0
    live, cached = split_cached(merged(d, "n2"))
    assert live == ["tmpci/alpha", "tmpci/beta"] and cached == []


def test_ci_gate_clean_then_regression(scope_file, tmp_path, capsys):
    d = str(tmp_path / "results")
    # generous gate: host timing noise on ~ns bodies must not flag, the
    # injected regression below is ~1000x
    ci = ["--results-dir", d, "--no-report", "--threshold", "2.0",
          *FAST_FLAGS]

    # first run measures everything, gate clean
    assert cli(ci_main, ["--run-id", "c1", *ci]) == 0
    live, cached = split_cached(merged(d, "c1"))
    assert live == ["tmpci/alpha", "tmpci/beta"] and cached == []
    records = hist.load_history(hist.history_path(d))
    assert all(r["tag"] == "ci" for r in records)

    # unchanged tree: zero measured, still exit 0
    assert cli(ci_main, ["--run-id", "c2", *ci]) == 0
    out = capsys.readouterr().out
    assert "0 measured" in out and "2 cached" in out

    # build a second real measurement so the drift window has depth
    assert cli(ci_main, ["--run-id", "c3", "--full", *ci]) == 0

    # inject a regression into beta only → ci re-measures it and fails
    rewrite(scope_file, beta_line=BETA_SLOW)
    assert cli(ci_main, ["--run-id", "c4", *ci]) == 1
    live, cached = split_cached(merged(d, "c4"))
    assert live == ["tmpci/beta"] and cached == ["tmpci/alpha"]
    records = hist.load_history(hist.history_path(d))
    beta = [r for r in records if r["run_id"] == "c4"
            and r["name"] == "tmpci/beta"]
    assert beta and not beta[0].get("cached")


def test_ci_usage_errors(scope_file, tmp_path):
    assert cli(ci_main, ["--results-dir", ""]) == 2
    assert cli(ci_main, ["--results-dir", str(tmp_path),
                         "--param", "nonsense"]) == 2
    assert cli(ci_main, ["--results-dir", str(tmp_path),
                         "--benchmark_filter", "no/such/bench"]) == 2
