"""HLO analyzer: flops/collectives/trip counts on known programs."""
import jax
import jax.numpy as jnp

from repro.roofline.analysis import model_flops, roofline_terms
from repro.roofline.hlo import analyze_hlo, cpu_widening_artifact_bytes


def test_scan_flops_exact():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        c, _ = jax.lax.scan(body, x, None, length=12)
        return c
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    st = analyze_hlo(compiled.as_text())
    assert st.flops == 12 * 2 * 128 * 256 * 256


def test_nested_scan_flops_exact():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), ()
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, ()
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    st = analyze_hlo(compiled.as_text())
    assert st.flops == 15 * 2 * 64 * 128 * 128


def test_dominant_term_selection():
    # per-device terms: peak 197e12 F/s, 819e9 B/s HBM, 50e9 B/s link
    t = roofline_terms("a", "s", "m", 256, flops=1e13, bytes_accessed=1e9,
                       coll_bytes=1e8, mflops=5e14)
    assert t.dominant == "compute"
    t2 = roofline_terms("a", "s", "m", 256, flops=1e10,
                        bytes_accessed=1e13, coll_bytes=1e9, mflops=1e12)
    assert t2.dominant == "memory"


def test_model_flops_moe_uses_active():
    from repro.models import get_config
    dense = get_config("llama3.2-1b")
    moe = get_config("deepseek-moe-16b")
    assert model_flops(moe, 1000) < 6 * moe.num_params() * 1000
    assert model_flops(dense, 1000) == 6 * dense.num_params() * 1000


def test_cpu_widening_artifact_detection():
    text = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %w = (s32[], bf16[8,64], f32[8,64], f32[4]) while(%t), condition=%c, body=%b
}
"""
    assert cpu_widening_artifact_bytes(text) == 8 * 64 * 4
