"""Typed parameter spaces: ParamSpace composition, fixtures, the
compile/run phase split, --param selection through every layer, and the
legacy-compat goldens (byte-identical names, plan IDs and merged.json
for int-only families)."""
import json
import os
import subprocess
import sys
import time

import pytest

from repro.core.benchmark import (Benchmark, ParamSpace, Params,
                                  format_value, match_params, name_params,
                                  parse_param_filter)
from repro.core.flags import FlagRegistry
from repro.core.hooks import HookChain
from repro.core.plan import build_plan, instance_id
from repro.core.registry import BenchmarkRegistry, benchmark
from repro.core.runner import (RESERVED_RECORD_KEYS, RunOptions,
                               run_benchmarks, run_single_instance)
from repro.core.scope import ScopeManager


def make_mgr(modules):
    mgr = ScopeManager(registry=BenchmarkRegistry(), flags=FlagRegistry(),
                       hooks=HookChain())
    mgr.load(modules)
    mgr.register_all()
    return mgr


# ---------------------------------------------------------------------------
# ParamSpace composition
# ---------------------------------------------------------------------------

def test_product_orders_axes_by_keyword():
    space = ParamSpace.product(dtype=["f32", "bf16"], n=[1, 2])
    assert space.axes() == ["dtype", "n"]
    assert [dict(p) for p in space] == [
        {"dtype": "f32", "n": 1}, {"dtype": "f32", "n": 2},
        {"dtype": "bf16", "n": 1}, {"dtype": "bf16", "n": 2}]


def test_zip_requires_equal_lengths():
    space = ParamSpace.zip(a=[1, 2], b=["x", "y"])
    assert [dict(p) for p in space] == [{"a": 1, "b": "x"},
                                        {"a": 2, "b": "y"}]
    with pytest.raises(ValueError, match="equal lengths"):
        ParamSpace.zip(a=[1, 2], b=["x"])


def test_cases_where_mul_add():
    space = (ParamSpace.product(backend=["xla", "pallas"], n=[256, 512])
             .where(lambda p: p.backend == "xla" or p.n == 256))
    assert len(space) == 3
    crossed = ParamSpace.cases({"a": 1}) * ParamSpace.cases({"b": 2},
                                                            {"b": 3})
    assert [dict(p) for p in crossed] == [{"a": 1, "b": 2},
                                          {"a": 1, "b": 3}]
    with pytest.raises(ValueError, match="sharing axes"):
        ParamSpace.cases({"a": 1}) * ParamSpace.cases({"a": 2})
    summed = ParamSpace.cases({"a": 1}) + ParamSpace.cases({"a": 2})
    assert len(summed) == 2


def test_duplicate_points_rejected():
    with pytest.raises(ValueError, match="duplicate parameter point"):
        ParamSpace.cases({"n": 1}, {"n": 1})
    with pytest.raises(ValueError, match="duplicate parameter point"):
        ParamSpace.cases({"n": 1}) + ParamSpace.cases({"n": 1})


def test_values_must_be_json_scalars():
    with pytest.raises(TypeError, match="JSON-able scalar"):
        ParamSpace.cases({"n": [1, 2]})
    # all four scalar kinds render canonically in names
    assert format_value(True) == "true"
    assert format_value(256) == "256"
    assert format_value("bf16") == "bf16"


def test_params_access_and_identity():
    p = Params({"dtype": "bf16", "n": 256, "fused": True})
    assert p.dtype == "bf16" and p["n"] == 256
    assert dict(p) == {"dtype": "bf16", "n": 256, "fused": True}
    assert p.int_values() == (256,)          # bools are not ranges
    assert p.canonical() == '{"dtype":"bf16","fused":true,"n":256}'
    with pytest.raises(AttributeError, match="no parameter axis"):
        p.missing
    with pytest.raises(AttributeError):
        p.dtype = "f32"


# ---------------------------------------------------------------------------
# Benchmark integration: naming, shim, mixing, duplicates
# ---------------------------------------------------------------------------

def test_typed_instance_names():
    b = Benchmark("s/mm", lambda s: None)
    b.param_space(ParamSpace.product(dtype=["f32", "bf16"], n=[256]))
    assert [n for n, _ in b.instances()] == \
        ["s/mm/dtype:f32/n:256", "s/mm/dtype:bf16/n:256"]


def test_state_range_shim_over_int_axes():
    got = {}

    def body(state):
        got["range0"] = state.range(0)
        got["dtype"] = state.params.dtype
        while state.keep_running():
            pass

    b = Benchmark("s/b", body)
    b.param_space(dtype=["bf16"], n=[512])
    doc = run_single_instance([b], "s/b/dtype:bf16/n:512",
                              RunOptions(min_time=0.001))
    assert got == {"range0": 512, "dtype": "bf16"}
    assert doc["benchmarks"][0]["name"] == "s/b/dtype:bf16/n:512"


def test_typed_and_legacy_sweeps_cannot_mix():
    b = Benchmark("s/b", lambda s: None).args([1])
    with pytest.raises(ValueError, match="typed or legacy"):
        b.param_space(n=[1])
    b2 = Benchmark("s/c", lambda s: None).param_space(n=[1])
    with pytest.raises(ValueError, match="typed or legacy"):
        b2.args([1])


def test_duplicate_arg_sets_rejected_at_registration():
    b = Benchmark("s/b", lambda s: None).args([8])
    with pytest.raises(ValueError, match="duplicate arg-set"):
        b.args([8])
    with pytest.raises(ValueError, match="duplicate arg-set"):
        Benchmark("s/c", lambda s: None).args_product([[1, 1], [2]])


def test_set_unit_raises_value_error():
    # was an assert, which `python -O` strips into silent corruption
    with pytest.raises(ValueError, match="unknown time unit"):
        Benchmark("s/b", lambda s: None).set_unit("parsec")


def test_build_plan_rejects_cross_family_name_collisions():
    mgr = make_mgr([])
    from repro.core.scope import Scope

    def _register(reg):
        benchmark(name="f/n:1", scope="s", registry=reg)(lambda s: None)
        benchmark(name="f", scope="s", registry=reg)(
            lambda s: None).param_space(n=[1])
    mgr.add_scope(Scope(name="s", register=_register))
    mgr.register_all()
    with pytest.raises(ValueError, match="duplicate benchmark instance"):
        build_plan(mgr, mgr.registry)


# ---------------------------------------------------------------------------
# fixtures + compile/run phase separation
# ---------------------------------------------------------------------------

def test_fixture_runs_once_untimed_before_calibration():
    setups = []

    def setup(params):
        setups.append(dict(params))
        time.sleep(0.05)                       # must never be timed
        return {"payload": params.n * 2}

    def body(state):
        assert state.fixture["payload"] == state.params.n * 2
        while state.keep_running():
            pass

    b = Benchmark("s/b", body).param_space(n=[4]).set_fixture(setup)
    doc = run_single_instance([b], "s/b/n:4", RunOptions(min_time=0.005))
    rec = doc["benchmarks"][0]
    assert setups == [{"n": 4}]                # once per instance
    # timed mean is harness-loop fast — the 50ms setup stayed outside
    assert rec["real_time"] < 1e3              # < 1ms in us units


def test_fixture_failure_degrades_to_error_record():
    def setup(params):
        raise RuntimeError("no device")

    b = Benchmark("s/b", lambda s: None).param_space(n=[1])
    b.set_fixture(setup)
    doc = run_single_instance([b], "s/b/n:1", RunOptions(min_time=0.001))
    rec = doc["benchmarks"][0]
    assert rec["error_occurred"] is True
    assert "fixture failed" in rec["error_message"]


def test_compile_time_recorded_per_instance():
    first_call = {"done": False}

    def body(state):
        if not first_call["done"]:             # jit-compile stand-in
            first_call["done"] = True
            time.sleep(0.03)
        while state.keep_running():
            pass

    b = Benchmark("s/b", body).param_space(n=[1])
    doc = run_single_instance([b], "s/b/n:1", RunOptions(min_time=0.005))
    rec = doc["benchmarks"][0]
    # warm phase caught the one-off compile; steady-state did not
    assert rec["compile_time_s"] >= 0.03
    assert rec["real_time"] < 0.03 * 1e6       # us
    # error records carry no compile time
    bad = Benchmark("s/bad", lambda s: s.skip_with_error("x"))
    bad.param_space(n=[1])
    err = run_single_instance([bad], "s/bad/n:1", RunOptions())
    assert "compile_time_s" not in err["benchmarks"][0]


def test_counter_shadowing_canonical_key_is_renamed():
    def body(state):
        while state.keep_running():
            pass
        state.counters["real_time"] = 123.0    # hostile counter name
        state.counters["good"] = 7.0

    reg = BenchmarkRegistry()
    benchmark(scope="t", registry=reg)(body).param_space(n=[1])
    doc = run_benchmarks(reg.all(), RunOptions(min_time=0.001),
                         progress=False)
    rec = doc["benchmarks"][0]
    assert rec["real_time"] != 123.0           # canonical key intact
    assert rec["counter_real_time"] == 123.0   # data preserved, renamed
    assert rec["good"] == 7.0
    assert "real_time" in RESERVED_RECORD_KEYS
    assert "compile_time_s" in RESERVED_RECORD_KEYS


# ---------------------------------------------------------------------------
# --param selection through every layer
# ---------------------------------------------------------------------------

def test_parse_and_match_param_filters():
    flt = parse_param_filter(["dtype=bf16", "dtype=f32", "n=256"])
    assert flt == {"dtype": ["bf16", "f32"], "n": ["256"]}
    assert parse_param_filter([]) is None
    with pytest.raises(ValueError, match="KEY=VALUE"):
        parse_param_filter(["dtype"])
    p = Params({"dtype": "bf16", "n": 256})
    assert match_params(p, flt)                          # OR within key
    assert not match_params(p, {"dtype": ["f64"]})
    assert not match_params(p, {"backend": ["xla"]})     # missing axis
    assert match_params(p, None)
    assert name_params("s/f/dtype:bf16/n:256") == {"dtype": "bf16",
                                                   "n": "256"}


def test_registry_filter_and_runner_honor_params():
    reg = BenchmarkRegistry()
    benchmark(name="mm", scope="t", registry=reg)(
        lambda s: None).param_space(dtype=["f32", "bf16"], n=[1])
    benchmark(name="plain", scope="t", registry=reg)(lambda s: None)
    flt = {"dtype": ["bf16"]}
    assert [b.name for b in reg.filter(params=flt)] == ["t/mm"]
    doc = run_benchmarks(reg.all(),
                         RunOptions(min_time=0.001, param_filter=flt),
                         progress=False)
    assert [r["name"] for r in doc["benchmarks"]] == \
        ["t/mm/dtype:bf16/n:1"]


def test_build_plan_prunes_at_instance_level():
    mgr = make_mgr(["repro.scopes.example_scope"])
    plan = build_plan(mgr, mgr.registry, param_filter={"dtype": ["f64"]})
    assert [i.name for i in plan.items] == \
        ["example/axpy/dtype:f64/n:16384"]
    assert plan.items[0].params_dict() == {"dtype": "f64", "n": 16384}
    # legacy named axes are addressable the same way
    plan2 = build_plan(mgr, mgr.registry, param_filter={"n": ["256"]})
    assert [i.name for i in plan2.items] == ["example/saxpy/n:256"]


def test_compare_cli_param_selection(tmp_path, capsys):
    from repro.core.baseline import compare_main

    def doc(us):
        return {"context": {}, "benchmarks": [
            {"name": n, "run_name": n, "run_type": "iteration",
             "iterations": 1, "real_time": t, "cpu_time": t,
             "time_unit": "us", "repetitions": 1, "repetition_index": 0,
             "threads": 1} for n, t in us.items()]}

    a, b = tmp_path / "a.json", tmp_path / "b.json"
    a.write_text(json.dumps(doc({"s/f/dtype:f32": 1.0,
                                 "s/f/dtype:bf16": 1.0})))
    b.write_text(json.dumps(doc({"s/f/dtype:f32": 99.0,   # regression
                                 "s/f/dtype:bf16": 1.0})))
    # full compare gates on the f32 regression; bf16-only compare passes
    assert compare_main([str(a), str(b)]) == 1
    capsys.readouterr()
    assert compare_main([str(a), str(b), "--param", "dtype=bf16"]) == 0
    out = capsys.readouterr().out
    assert "dtype:bf16" in out and "dtype:f32" not in out


# ---------------------------------------------------------------------------
# legacy-compat goldens
# ---------------------------------------------------------------------------

# Recorded from the pre-ParamSpace seed: int-only families must keep
# these exact names and plan IDs across the redesign (resumability and
# history continuity depend on it).
LEGACY_GOLDEN = {
    "example/noop": "example_noop-a7aa4457",
    "example/saxpy/n:256": "example_saxpy_n_256-8f19a9a1",
    "example/saxpy/n:1024": "example_saxpy_n_1024-98cc1f8a",
    "example/saxpy/n:4096": "example_saxpy_n_4096-4c8fd2a9",
    "example/saxpy/n:16384": "example_saxpy_n_16384-22be85fe",
    "example/saxpy/n:65536": "example_saxpy_n_65536-a88a80fa",
}


def test_legacy_int_families_keep_names_and_plan_ids():
    mgr = make_mgr(["repro.scopes.example_scope"])
    plan = build_plan(mgr, mgr.registry, pattern="noop|saxpy")
    assert {i.name: i.instance_id for i in plan.items} == LEGACY_GOLDEN
    # and the ID function itself is still name-derived
    for name, iid in LEGACY_GOLDEN.items():
        assert instance_id(name) == iid


def _normalized_merged(doc):
    """merged.json with volatile measurement fields zeroed — what must
    be byte-identical across two runs of the same legacy plan."""
    out = {"benchmarks": []}
    for rec in doc["benchmarks"]:
        r = dict(rec)
        for k in ("real_time", "cpu_time", "compile_time_s",
                  "bytes_per_second", "items_per_second", "iterations"):
            r.pop(k, None)
        out["benchmarks"].append(r)
    return json.dumps(out, indent=2, sort_keys=True)


def test_merged_json_byte_identical_for_legacy_families(tmp_path):
    """Golden compat: two benchmark-grained runs of an int-only legacy
    family produce byte-identical merged.json once measurement noise is
    stripped — names, order, schema, params all stable."""
    from repro.core.orchestrate import OrchestratorOptions, execute
    docs = []
    for run_id in ("g1", "g2"):
        mgr = make_mgr(["repro.scopes.example_scope"])
        res = execute(mgr, mgr.registry, OrchestratorOptions(
            jobs=1, isolate="inline", shard_grain="benchmark",
            benchmark_filter="saxpy",
            run=RunOptions(min_time=0.001),
            results_dir=str(tmp_path), run_id=run_id))
        with open(os.path.join(res.out_dir, "merged.json")) as f:
            docs.append(json.load(f))
    assert _normalized_merged(docs[0]) == _normalized_merged(docs[1])
    assert [r["name"] for r in docs[0]["benchmarks"]] == \
        [n for n in LEGACY_GOLDEN if "saxpy" in n]
    # manifest round-trips the typed view of the legacy axes
    manifest = json.load(open(tmp_path / "g1" / "manifest.json"))
    assert manifest["items"][0]["params"] == {"n": 256}


# ---------------------------------------------------------------------------
# end-to-end: plan → shard → merge → history → report
# ---------------------------------------------------------------------------

def test_param_space_end_to_end(tmp_path):
    """A typed family flows through the whole pipeline: plan grain
    shards per instance, merged.json carries params + compile_time_s,
    history round-trips the names, the report renders."""
    from repro.core.orchestrate import OrchestratorOptions, execute
    from repro.core import history as hist_mod
    from repro.scopeplot.report import generate_run_report

    results = tmp_path / "results"
    for run_id in ("e2e-1", "e2e-2"):          # two runs → trend + verdicts
        mgr = make_mgr(["repro.scopes.example_scope"])
        res = execute(mgr, mgr.registry, OrchestratorOptions(
            jobs=1, isolate="inline", shard_grain="benchmark",
            benchmark_filter="axpy",           # matches axpy + saxpy
            run=RunOptions(min_time=0.001),
            results_dir=str(results), run_id=run_id))
        assert all(r.status == "ok" for r in res.instances)

    out = results / "e2e-2"
    manifest = json.load(open(out / "manifest.json"))
    typed = [i for i in manifest["items"]
             if i["family"] == "example/axpy"]
    assert [i["params"] for i in typed] == [
        {"dtype": "f32", "n": 16384}, {"dtype": "f64", "n": 16384}]
    # one shard per instance, named by the stable ID
    for i in typed:
        assert (out / i["shard"]).exists()

    merged = json.load(open(out / "merged.json"))
    by_name = {r["name"]: r for r in merged["benchmarks"]}
    for name in ("example/axpy/dtype:f32/n:16384",
                 "example/axpy/dtype:f64/n:16384"):
        assert by_name[name]["compile_time_s"] > 0

    # history: typed names round-trip, second run gets a verdict
    records = hist_mod.load_history(str(results / "history.jsonl"))
    series = hist_mod.series(records, "example/axpy/dtype:f64/n:16384")
    assert [r["run_id"] for r in series] == ["e2e-1", "e2e-2"]
    assert series[0]["verdict"] == "new"
    assert series[1]["verdict"] in ("similar", "improvement", "regression")

    # report renders with the compile column and the typed names
    paths = generate_run_report(str(out))
    md = open(paths["md"]).read()
    assert "example/axpy/dtype:bf16" not in md
    assert "example/axpy/dtype:f32/n:16384" in md
    assert "| compile |" in md.replace("compile ", "compile ")
    assert os.path.exists(paths["html"])


def test_series_by_param_plots_dtype_as_series(tmp_path):
    """One spec + group_by plots each dtype as its own series instead
    of needing a hand-written series per family clone."""
    from repro.scopeplot.plot import load_spec, render_spec
    import yaml

    doc = {"context": {}, "benchmarks": [
        {"name": f"s/mm/dtype:{d}/n:{n}", "run_name": f"s/mm/dtype:{d}/n:{n}",
         "run_type": "iteration", "iterations": 1, "real_time": t,
         "cpu_time": t, "time_unit": "us", "repetitions": 1,
         "repetition_index": 0, "threads": 1}
        for d, n, t in [("f32", 256, 1.0), ("f32", 512, 2.0),
                        ("bf16", 256, 0.5), ("bf16", 512, 1.0)]]}
    src = tmp_path / "r.json"
    src.write_text(json.dumps(doc))
    spec_path = tmp_path / "spec.yaml"
    spec_path.write_text(yaml.safe_dump({
        "title": "mm by dtype", "type": "line",
        "output": "mm.png",
        "series": [{"input_file": "r.json", "regex": "s/mm",
                    "group_by": "dtype", "xfield": "n"}],
    }))
    spec = load_spec(str(spec_path))
    out = render_spec(spec, base_dir=str(tmp_path))
    assert os.path.exists(out)

    # filter_params + param_values back the expansion
    from repro.scopeplot.model import loads
    bf = loads(json.dumps(doc))
    assert bf.param_values("dtype") == ["f32", "bf16"]
    assert [r.name for r in bf.filter_params({"dtype": "bf16"})] == \
        ["s/mm/dtype:bf16/n:256", "s/mm/dtype:bf16/n:512"]

    # aggregate records (display name suffixed "_stddev") parse their
    # params from run_name — no phantom "256_stddev" axis value, and
    # filtering keeps the instance's aggregates (error bars survive)
    agg = loads(json.dumps({"context": {}, "benchmarks": [
        {"name": "s/mm/dtype:f32/n:256_stddev",
         "run_name": "s/mm/dtype:f32/n:256", "run_type": "aggregate",
         "aggregate_name": "stddev", "iterations": 1, "real_time": 0.1,
         "cpu_time": 0.1, "time_unit": "us", "repetitions": 2,
         "repetition_index": 0, "threads": 1}]}))
    assert agg.records[0].params == {"dtype": "f32", "n": "256"}
    assert agg.param_values("n") == ["256"]
    assert len(agg.filter_params({"n": "256"})) == 1

    # group_by is rejected where it can't work
    from repro.scopeplot.plot import SpecError
    bad = tmp_path / "bad.yaml"
    bad.write_text(yaml.safe_dump({
        "type": "timeseries", "output": "x.png",
        "series": [{"input_file": "h.jsonl", "group_by": "dtype"}]}))
    with pytest.raises(SpecError, match="group_by"):
        load_spec(str(bad))


def test_run_cli_param_selection_subprocess(tmp_path):
    """`python -m repro run --param dtype=f32 --jobs 2`: the manifest
    holds only matching instances (the CI smoke assertion, in-tree)."""
    # inherit the environment (JAX_PLATFORMS etc.) — a bare env makes
    # the worker's jax backend probe crawl on exotic containers
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro", "run",
         "--enable-scope", "example", "--param", "dtype=f32",
         "--jobs", "2", "--shard-grain", "benchmark",
         "--results-dir", str(tmp_path), "--run-id", "psmoke",
         "--benchmark_min_time", "0.001",
         "--benchmark_out", os.devnull],
        capture_output=True, text=True, env=env, cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    manifest = json.load(open(tmp_path / "psmoke" / "manifest.json"))
    assert manifest["items"], "param filter selected nothing"
    assert all(i["params"].get("dtype") == "f32"
               for i in manifest["items"])
    assert all(i["status"] == "ok" for i in manifest["items"])
