"""Docs stay honest: no dead relative links in README/docs, and every
--help example still appears in its epilog AND still parses against the
current argument surface (so examples can't rot)."""
import importlib.util
import os
import shlex

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_spec = importlib.util.spec_from_file_location(
    "check_links", os.path.join(REPO, "scripts", "check_links.py"))
check_links = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_links)


# ---------------------------------------------------------------------------
# link checker (same code CI runs)
# ---------------------------------------------------------------------------

def test_no_dead_links_in_docs():
    roots = [os.path.join(REPO, p) for p in ("README.md", "docs",
                                             "ROADMAP.md")]
    files = check_links.markdown_files(roots)
    assert len(files) >= 4           # README + ROADMAP + 3 docs pages
    dead = {md: check_links.dead_links(md) for md in files}
    assert all(not v for v in dead.values()), \
        {k: v for k, v in dead.items() if v}


def test_link_checker_catches_dead_links(tmp_path):
    md = tmp_path / "x.md"
    md.write_text("[ok](x.md) [dead](missing.md) "
                  "[ext](https://example.com) [anchor](#sec) "
                  "![img](gone.png)\n[ref]: also-gone.md\n")
    dead = check_links.dead_links(str(md))
    assert sorted(t for t, _ in dead) == \
        ["also-gone.md", "gone.png", "missing.md"]
    assert check_links.main([str(md)]) == 1
    ok = tmp_path / "ok.md"
    ok.write_text("[self](ok.md)\n")
    assert check_links.main([str(ok)]) == 0


# ---------------------------------------------------------------------------
# --help epilogs: examples present and parseable
# ---------------------------------------------------------------------------

def _parsers():
    from repro.core.baseline import build_compare_parser
    from repro.core.ci import build_ci_parser
    from repro.core.lint import build_lint_parser
    from repro.core.main import build_plan_parser, build_run_parser
    from repro.core.tune import build_tune_parser
    from repro.scopeplot.report import build_report_parser
    from repro.store.cli import build_query_parser, build_store_parser
    return {"run": build_run_parser(), "plan": build_plan_parser(),
            "ci": build_ci_parser(),
            "tune": build_tune_parser(),
            "lint": build_lint_parser(),
            "compare": build_compare_parser(),
            "report": build_report_parser(),
            "query": build_query_parser(),
            "store": build_store_parser()}


def test_examples_cover_every_subcommand():
    from repro.core.cli_examples import EXAMPLES
    assert set(EXAMPLES) == {"run", "plan", "ci", "tune", "lint",
                             "compare", "report", "query", "store"}
    assert all(EXAMPLES[k] for k in EXAMPLES)


def test_examples_appear_in_help_epilogs():
    from repro.core.cli_examples import EXAMPLES
    parsers = _parsers()
    for cmd, examples in EXAMPLES.items():
        help_text = parsers[cmd].format_help()
        for _, example in examples:
            assert example in help_text, (cmd, example)


def test_examples_still_parse():
    """Every example command line round-trips through the real parser
    for its subcommand; leftover tokens must be declared scope/core
    flags (the FLAGS registry), not typos."""
    from repro.core.cli_examples import EXAMPLES
    from repro.core.flags import FLAGS
    parsers = _parsers()
    for cmd, examples in EXAMPLES.items():
        for _, example in examples:
            tokens = shlex.split(example)
            assert tokens[:3] == ["python", "-m", "repro"], example
            assert tokens[3] == cmd, example
            ns, rest = parsers[cmd].parse_known_args(tokens[4:])
            if rest:
                flag_parser = FLAGS.build_parser()
                _, unknown = flag_parser.parse_known_args(rest)
                assert unknown == [], (example, unknown)


def test_top_level_help(capsys):
    from repro.core.main import main
    assert main(["--help"]) == 0
    out = capsys.readouterr().out
    for cmd in ("run", "plan", "ci", "tune", "lint", "compare",
                "report", "query", "store"):
        assert cmd in out
    assert "examples:" in out


def test_plan_and_compare_help(capsys):
    from repro.core.main import plan_main
    assert plan_main(["--help"]) == 0
    assert "python -m repro plan --jobs 4" in capsys.readouterr().out
    from repro.core.baseline import build_compare_parser
    with pytest.raises(SystemExit) as e:
        build_compare_parser().parse_args(["--help"])
    assert e.value.code == 0
    assert "history.jsonl" in capsys.readouterr().out


def test_run_help_includes_scope_flags(capsys):
    from repro.core.main import run_main
    assert run_main(["--help"],
                    scope_modules=["repro.scopes.example_scope"]) == 0
    out = capsys.readouterr().out
    assert "--jobs" in out
    assert "scope flags" in out
    assert "--benchmark_filter" in out or "--benchmark.filter" in out
