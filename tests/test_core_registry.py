"""Core benchmark registry: registration, sweeps, filtering (paper §III)."""
import pytest
from hypothesis_compat import given, settings, st

from repro.core.benchmark import Benchmark, State
from repro.core.registry import BenchmarkRegistry, benchmark


def make_registry():
    return BenchmarkRegistry()


def test_register_and_filter():
    reg = make_registry()

    @benchmark(scope="s1", registry=reg)
    def foo(state):
        pass

    @benchmark(scope="s2", registry=reg)
    def bar(state):
        pass

    assert len(reg) == 2
    assert [b.name for b in reg.filter("foo")] == ["s1/foo"]
    assert [b.name for b in reg.filter(".*", scopes=["s2"])] == ["s2/bar"]
    assert reg.filter("nomatch") == []


def test_duplicate_rejected():
    reg = make_registry()

    @benchmark(scope="s", registry=reg)
    def foo(state):
        pass

    with pytest.raises(ValueError):
        benchmark(name="foo", scope="s", registry=reg)(lambda s: None)


def test_instance_names_args():
    b = Benchmark("s/b", lambda s: None)
    b.args([1, 2]).args([3, 4]).set_arg_names(["x", "y"])
    names = [n for n, _ in b.instances()]
    assert names == ["s/b/x:1/y:2", "s/b/x:3/y:4"]


def test_range_multiplier():
    b = Benchmark("s/b", lambda s: None).range_multiplier_args(8, 64, mult=2)
    assert [a[0] for a in b.arg_sets] == [8, 16, 32, 64]


def test_remove_scope():
    reg = make_registry()
    benchmark(scope="a", registry=reg)(lambda state: None)
    reg.remove_scope("a")
    assert len(reg) == 0


@given(st.lists(st.lists(st.integers(1, 8), min_size=1, max_size=3),
                min_size=1, max_size=3))
@settings(max_examples=25, deadline=None)
def test_args_product_cardinality(lists):
    b = Benchmark("s/b", lambda s: None).args_product(lists)
    expect = 1
    for l in lists:
        expect *= len(l)
    assert len(b.arg_sets) == expect
    # every combo unique positions match input lists
    for combo in b.arg_sets:
        for i, v in enumerate(combo):
            assert v in lists[i]


def test_state_iteration_protocol():
    st_ = State(ranges=(5,), max_iterations=7)
    n = 0
    while st_.keep_running():
        n += 1
    assert n == 7 and st_.iterations == 7
    assert st_.range(0) == 5
    assert st_.elapsed > 0


def test_state_skip_with_error():
    st_ = State(max_iterations=100)
    st_.skip_with_error("boom")
    assert not st_.keep_running()
    assert st_.error_occurred
