"""Measurement meters: the wall-clock fence, the wall/CPU split, the
cost-model counters, aggregate carrying, and the meter selection flag
(repro.core.measure / the runner's MeterStack integration)."""
import json
import os
import time

import pytest

from repro.core import ParamSpace
from repro.core.baseline import collect_stats, compare_documents
from repro.core.history import append_run, doc_counters, load_history
from repro.core.measure import (CostModelMeter, CpuTimeMeter, DEFAULT_METERS,
                                MeterStack, WallClockMeter, parse_meters)
from repro.core.registry import BenchmarkRegistry, benchmark
from repro.core.runner import RunOptions, run_benchmarks

ALL_METERS = RunOptions(min_time=0.002,
                        meters=["wall", "cpu", "costmodel"])


def _records(doc, run_type="iteration"):
    return [r for r in doc["benchmarks"] if r["run_type"] == run_type]


def _matmul_family(reg, n=64, chain=1, name="mm", **bench_kwargs):
    """A jax matmul family following the (fn, *operands) fixture
    convention; ``chain`` stacks matmuls to scale the work."""
    import jax
    import jax.numpy as jnp

    def setup(params):
        def body(x, y):
            out = y
            for _ in range(chain):
                out = x @ out
            return out
        return (jax.jit(body),
                jnp.ones((params.n, params.n), jnp.float32),
                jnp.ones((params.n, params.n), jnp.float32))

    @benchmark(name=name, scope="t", registry=reg, **bench_kwargs)
    def mm(state):
        fn, x, y = state.fixture
        while state.keep_running():
            state.deliver(fn(x, y))
    mm.param_space(ParamSpace.product(n=[n]))
    mm.set_fixture(setup)
    return mm


# ---------------------------------------------------------------------------
# WallClockMeter: the fence runs before the clock stops
# ---------------------------------------------------------------------------

def test_wall_meter_fence_inside_timed_window():
    """The sync hook's cost lands inside real_time — proof the fence
    runs before the stop timestamp is captured."""
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def b(state):
        while state.keep_running():
            pass
    b.set_iterations(1)
    b.set_sync(lambda ctx: time.sleep(0.05))

    doc = run_benchmarks(reg.all(), RunOptions(), progress=False)
    rec = _records(doc)[0]
    assert rec["real_time"] >= 0.05 * 1e6        # us


def test_default_sync_blocks_on_deliverables():
    """An async jax body that only *delivers* its output is fenced by
    the default sync: the measured time must cover the device work, so
    it is strictly larger than the same body with the fence disabled
    (which measures enqueue cost only)."""
    jax = pytest.importorskip("jax")  # noqa: F841 - body imports it
    reg = BenchmarkRegistry()
    # enough chained matmuls that compute time dwarfs dispatch time
    _matmul_family(reg, n=512, chain=8, name="fenced")
    unfenced = _matmul_family(reg, n=512, chain=8, name="unfenced")
    unfenced.set_sync(lambda ctx: None)
    for fam in reg.all():
        fam.set_iterations(3)

    try:
        doc = run_benchmarks(reg.all(), RunOptions(), progress=False)
    finally:
        # the unfenced family's dispatched matmuls are still draining in
        # XLA's thread pool (freeing the outputs does not cancel them);
        # CPU PJRT executes per-device work in enqueue order, so block
        # on a freshly *enqueued* computation to drain the queue — their
        # CPU burn must not pollute the process_time window of whatever
        # test runs next
        import jax.numpy as jnp
        jax.jit(lambda x: x + 1)(jnp.zeros(())).block_until_ready()
    by_name = {r["name"]: r for r in _records(doc)}
    fenced_t = by_name["t/fenced/n:512"]["real_time"]
    unfenced_t = by_name["t/unfenced/n:512"]["real_time"]
    assert fenced_t > unfenced_t, (fenced_t, unfenced_t)


# ---------------------------------------------------------------------------
# CpuTimeMeter: a real wall/CPU split
# ---------------------------------------------------------------------------

def test_cpu_time_is_not_a_copy_of_real_time():
    """A sleeping body burns wall time but almost no CPU: cpu_time must
    come out well below real_time, in iteration AND aggregate records
    (it used to be a silent copy in both)."""
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def sleeper(state):
        while state.keep_running():
            time.sleep(0.03)
    sleeper.set_iterations(2)

    doc = run_benchmarks(reg.all(), RunOptions(repetitions=2),
                         progress=False)
    # 0.7, not ~0: this sandbox's process_time has 10ms ticks, and a
    # stray tick against the 60ms sleeping batch must not flake
    for rec in _records(doc):
        assert rec["cpu_time"] < rec["real_time"] * 0.7, rec
    means = [r for r in _records(doc, "aggregate")
             if r["aggregate_name"] == "mean"]
    assert means and all(r["cpu_time"] < r["real_time"] * 0.7
                         for r in means)


def test_cpu_time_tracks_busy_work():
    """A busy body's CPU time is the same order as its wall time —
    the meter measures the timed window, not some unrelated clock.
    (Iterations sized so the batch clears coarse process_time ticks.)"""
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def busy(state):
        while state.keep_running():
            x = 0
            for i in range(200000):
                x += i * i
    busy.set_iterations(10)

    doc = run_benchmarks(reg.all(), RunOptions(), progress=False)
    rec = _records(doc)[0]
    assert rec["cpu_time"] > rec["real_time"] * 0.3, rec


def test_pause_timing_excludes_cpu_too():
    """pause/resume carves the same sections out of both clocks."""
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def paused(state):
        while state.keep_running():
            state.pause_timing()
            x = 0
            for i in range(400000):      # heavy CPU, all excluded
                x += i * i
            state.resume_timing()
    paused.set_iterations(3)

    doc = run_benchmarks(reg.all(), RunOptions(), progress=False)
    rec = _records(doc)[0]
    assert rec["cpu_time"] * 1e-6 < 0.05          # us → s


# ---------------------------------------------------------------------------
# CostModelMeter: static flops/bytes from the fixture's callable
# ---------------------------------------------------------------------------

def test_cost_model_counters_exact_for_matmul():
    pytest.importorskip("jax")
    reg = BenchmarkRegistry()
    _matmul_family(reg, n=64)
    doc = run_benchmarks(reg.all(), ALL_METERS, progress=False)
    rec = _records(doc)[0]
    assert rec["flops"] == 2.0 * 64 ** 3
    assert rec["bytes_accessed"] > 0
    assert rec["arithmetic_intensity"] == \
        rec["flops"] / rec["bytes_accessed"]
    assert rec["flops_per_second"] > 0
    # achieved rate is flops per measured second
    per_iter_s = rec["real_time"] * 1e-6
    assert rec["flops_per_second"] == pytest.approx(
        rec["flops"] / per_iter_s, rel=1e-6)


def test_cost_model_analysis_runs_once_in_prepare(monkeypatch):
    """The expensive lowering happens in prepare (untimed, before the
    warm batch) and is cached per parameter point — batches never pay
    it again, and compile_time_s can't absorb it."""
    pytest.importorskip("jax")
    from repro.core.benchmark import Params, State

    meter = CostModelMeter()
    calls = []
    real = meter._analyze
    monkeypatch.setattr(meter, "_analyze",
                        lambda st: calls.append(1) or real(st))
    import jax
    import jax.numpy as jnp
    fixture = (jax.jit(jnp.dot), jnp.ones((16, 16)), jnp.ones((16, 16)))
    st = State(params=Params({"n": 16}), fixture=fixture)
    meter.prepare(st)
    assert calls == [1]
    out = meter.end(st)
    assert out["flops"] == 2.0 * 16 ** 3
    assert calls == [1]                       # cached, not re-analyzed


def test_cost_model_degrades_without_fixture_convention():
    """A family whose fixture isn't (fn, *args) gets no cost counters —
    and no error."""
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def plain(state):
        while state.keep_running():
            pass
    doc = run_benchmarks(reg.all(), ALL_METERS, progress=False)
    rec = _records(doc)[0]
    assert "flops" not in rec and not rec.get("error_occurred")


def test_body_counters_win_over_meter_metrics():
    pytest.importorskip("jax")
    reg = BenchmarkRegistry()

    import jax
    import jax.numpy as jnp

    def setup(params):
        return jax.jit(jnp.dot), jnp.ones((8, 8)), jnp.ones((8, 8))

    @benchmark(scope="t", registry=reg)
    def mm(state):
        fn, x, y = state.fixture
        while state.keep_running():
            state.deliver(fn(x, y))
        state.counters["flops"] = 123.0       # body's claim wins
    mm.param_space(ParamSpace.product(n=[8]))
    mm.set_fixture(setup)

    doc = run_benchmarks(reg.all(), ALL_METERS, progress=False)
    assert _records(doc)[0]["flops"] == 123.0


# ---------------------------------------------------------------------------
# meter selection
# ---------------------------------------------------------------------------

def test_parse_meters():
    assert parse_meters("wall,cpu,costmodel") == ["wall", "cpu",
                                                  "costmodel"]
    assert parse_meters("cpu, wall") == ["cpu", "wall"]
    with pytest.raises(ValueError):
        parse_meters("wall,tpu_profiler")
    with pytest.raises(ValueError):
        parse_meters(",")


def test_stack_always_includes_wall_and_cpu():
    """Selecting an opt-in meter must not drop the time sources: a
    stack without the CPU meter would silently revert cpu_time to a
    copy of real_time."""
    from repro.core.benchmark import Benchmark
    bench = Benchmark(name="t/x", fn=lambda s: None, scope="t")
    stack = MeterStack.build(["costmodel"], bench)
    assert [type(m) for m in stack.meters] == \
        [WallClockMeter, CpuTimeMeter, CostModelMeter]
    stack = MeterStack.build(None, bench)
    assert [type(m) for m in stack.meters] == \
        [WallClockMeter, CpuTimeMeter]
    assert list(DEFAULT_METERS) == ["wall", "cpu"]
    with pytest.raises(ValueError, match="unknown meter"):
        MeterStack.build(["wall", "costmodl"], bench)


def test_set_meters_rejects_unknown_names_at_registration():
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def b(state):
        while state.keep_running():
            pass
    with pytest.raises(ValueError, match="unknown meter"):
        b.set_meters("costmodl")


def test_weak_fence_warns_once_for_undelivering_jax_body():
    """A jax-fixture body that never delivers gets the inputs-only
    fallback fence plus a one-time warning that its numbers may be
    enqueue cost."""
    import logging as _logging

    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core import measure

    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def undelivering(state):
        fn, x = state.fixture
        while state.keep_running():
            fn(x)                       # neither deliver nor sync
    undelivering.param_space(ParamSpace.product(n=[64]))
    undelivering.set_fixture(
        lambda params: (jax.jit(jnp.exp), jnp.ones((params.n,))))
    undelivering.set_iterations(2)

    records = []

    class Capture(_logging.Handler):
        def emit(self, record):
            records.append(record.getMessage())

    handler = Capture()
    measure.log.addHandler(handler)
    try:
        measure._WEAK_FENCE_WARNED.discard("t/undelivering")
        run_benchmarks(reg.all(), RunOptions(), progress=False)
        assert "t/undelivering" in measure._WEAK_FENCE_WARNED
        hits = [m for m in records if "never declared deliverables" in m]
        assert len(hits) == 1
        # warned once per family, not per batch — a second run is quiet
        run_benchmarks(reg.all(), RunOptions(), progress=False)
        hits = [m for m in records if "never declared deliverables" in m]
        assert len(hits) == 1
    finally:
        measure.log.removeHandler(handler)


def test_shared_cost_meter_keys_cache_by_family():
    """One CostModelMeter instance shared across families must not
    hand family A's flops to family B just because both sweep the
    same axis values."""
    pytest.importorskip("jax")
    import jax
    import jax.numpy as jnp
    reg = BenchmarkRegistry()
    shared = CostModelMeter()

    mm = _matmul_family(reg, n=32, name="mm")
    mm.set_meters(shared)

    def exp_setup(params):
        return jax.jit(jnp.exp), jnp.ones((params.n,), jnp.float32)

    @benchmark(scope="t", registry=reg)
    def ew(state):
        fn, x = state.fixture
        while state.keep_running():
            state.deliver(fn(x))
    ew.param_space(ParamSpace.product(n=[32]))     # same point: n=32
    ew.set_fixture(exp_setup)
    ew.set_meters(shared)

    doc = run_benchmarks(reg.all(), RunOptions(min_time=0.002),
                         progress=False)
    by_name = {r["name"]: r for r in _records(doc)}
    assert by_name["t/mm/n:32"]["flops"] == 2.0 * 32 ** 3
    # exp over 32 floats: whatever the fallback reports, it is NOT the
    # matmul's flops/bytes smuggled in through a shared cache entry
    assert by_name["t/ew/n:32"].get("flops") != 2.0 * 32 ** 3
    assert by_name["t/ew/n:32"].get("bytes_accessed") != \
        by_name["t/mm/n:32"]["bytes_accessed"]


def test_manual_time_families_are_not_fenced():
    """Manual-time bodies own their timing: the auto timer window is
    unused, so the fence must not run (nor warn) for them."""
    reg = BenchmarkRegistry()
    fenced = []

    @benchmark(scope="t", registry=reg)
    def manual(state):
        while state.keep_running():
            state.set_iteration_time(0.001)
    manual.manual_time().set_iterations(2)
    manual.set_sync(lambda ctx: fenced.append(1))

    doc = run_benchmarks(reg.all(), RunOptions(), progress=False)
    rec = _records(doc)[0]
    assert rec["real_time"] == pytest.approx(0.001 * 1e6)   # manual, us
    assert not fenced


def test_family_set_meters_overrides_run_selection():
    """A family can pin its own meter set — here an instance-level
    CostModelMeter even though the run asked for wall only."""
    pytest.importorskip("jax")
    reg = BenchmarkRegistry()
    fam = _matmul_family(reg, n=32)
    fam.set_meters("wall", CostModelMeter())
    doc = run_benchmarks(reg.all(), RunOptions(min_time=0.002,
                                               meters=["wall"]),
                         progress=False)
    assert _records(doc)[0]["flops"] == 2.0 * 32 ** 3


# ---------------------------------------------------------------------------
# aggregates carry the full measurement surface
# ---------------------------------------------------------------------------

def _throughput_doc(aggregates_only=False):
    reg = BenchmarkRegistry()

    @benchmark(scope="t", registry=reg)
    def b(state):
        while state.keep_running():
            time.sleep(0.001)
        state.set_bytes_processed(4096)
        state.set_items_processed(1024)
        state.counters["custom"] = 7.0
    b.set_iterations(2)
    return run_benchmarks(
        reg.all(),
        RunOptions(repetitions=3, report_aggregates_only=aggregates_only),
        progress=False)


def test_aggregates_carry_throughput_compile_and_counters():
    doc = _throughput_doc()
    aggs = {r["aggregate_name"]: r for r in _records(doc, "aggregate")}
    assert set(aggs) == {"mean", "median", "stddev"}
    for name in ("mean", "median"):
        rec = aggs[name]
        assert rec["bytes_per_second"] > 0
        assert rec["items_per_second"] > 0
        assert rec["compile_time_s"] > 0
        assert rec["custom"] == 7.0
    assert "compile_time_s" not in aggs["stddev"]
    assert aggs["stddev"]["custom"] == 0.0       # stddev of a constant


def test_aggregates_only_documents_stay_comparable():
    """--aggregates-only output still compares and appends to history:
    collect_stats falls back to the aggregate statistics."""
    doc = _throughput_doc(aggregates_only=True)
    assert all(r["run_type"] == "aggregate" for r in doc["benchmarks"])
    stats = collect_stats(doc)
    st = stats["t/b"]
    assert st.has_times and st.n == 3 and st.mean > 0
    comps = compare_documents(doc, doc)
    assert [c.verdict for c in comps] == ["similar"]


def test_aggregate_repetitions_count_successful_reps_only():
    """An errored repetition contributes no sample, so the aggregate's
    repetitions field (and Stats.n reconstructed from it) must not
    claim more samples than the statistics are computed over."""
    reg = BenchmarkRegistry()
    calls = {"n": 0}

    @benchmark(scope="t", registry=reg)
    def flaky(state):
        calls["n"] += 1
        if calls["n"] == 4:              # warm, cal, rep0 ok; rep1 errors
            state.skip_with_error("flaked")
            return
        while state.keep_running():
            time.sleep(0.001)
    flaky.set_iterations(2)

    doc = run_benchmarks(reg.all(), RunOptions(repetitions=3),
                         progress=False)
    aggs = [r for r in doc["benchmarks"] if r["run_type"] == "aggregate"]
    assert aggs and all(r["repetitions"] == 2 for r in aggs)
    st = collect_stats(doc)["t/flaky"]
    assert st.n == 2 and st.errors == 1


# ---------------------------------------------------------------------------
# counters → history
# ---------------------------------------------------------------------------

def test_meter_counters_land_in_history(tmp_path):
    pytest.importorskip("jax")
    reg = BenchmarkRegistry()
    _matmul_family(reg, n=64)
    doc = run_benchmarks(reg.all(), ALL_METERS, progress=False)
    counters = doc_counters(doc)
    assert counters["t/mm/n:64"]["flops"] == 2.0 * 64 ** 3

    recs = append_run(str(tmp_path), doc, run_id="r1")
    assert recs and recs[0]["counters"]["flops"] == 2.0 * 64 ** 3
    stored = load_history(os.path.join(str(tmp_path), "history.jsonl"))
    assert stored[0]["counters"]["bytes_accessed"] > 0


# ---------------------------------------------------------------------------
# end-to-end: plan → shard (subprocess workers) → merge → history
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_meters_survive_subprocess_workers(monkeypatch, tmp_path):
    """--meters travels through the plan-grain worker JSON: counters
    measured in a fresh interpreter land in the instance shard, the
    merged document, and history.jsonl."""
    from repro.core.flags import FlagRegistry
    from repro.core.hooks import HookChain
    from repro.core.orchestrate import OrchestratorOptions, execute
    from repro.core.scope import ScopeManager

    parts = [os.path.abspath("src")]
    if os.environ.get("PYTHONPATH"):
        parts.append(os.environ["PYTHONPATH"])
    monkeypatch.setenv("PYTHONPATH", os.pathsep.join(parts))

    mgr = ScopeManager(registry=BenchmarkRegistry(), flags=FlagRegistry(),
                       hooks=HookChain())
    mgr.load(["repro.scopes.mxu_scope"])
    mgr.register_all()
    res = execute(mgr, mgr.registry, OrchestratorOptions(
        jobs=2, isolate="subprocess", shard_grain="benchmark",
        run=RunOptions(min_time=0.002,
                       meters=["wall", "cpu", "costmodel"],
                       param_filter={"backend": ["xla"], "dtype": ["f32"]}),
        results_dir=str(tmp_path), run_id="meters-e2e"))

    recs = [r for r in res.doc["benchmarks"]
            if not r.get("error_occurred")]
    assert recs, res.doc["benchmarks"]
    for rec in recs:
        n = int(rec["name"].rsplit(":", 1)[1])
        assert rec["flops"] == 2.0 * n ** 3, rec
        assert rec["bytes_accessed"] > 0
        assert rec["cpu_time"] != rec["real_time"]

    # the per-instance spool shards carry the counters too
    shard_dir = tmp_path / "meters-e2e" / "shards"
    shard_docs = [json.loads(p.read_text())
                  for p in shard_dir.glob("*.json")]
    assert shard_docs and all(
        "flops" in r for d in shard_docs for r in d["benchmarks"])

    hist = load_history(str(tmp_path / "history.jsonl"))
    by_name = {r["name"]: r for r in hist}
    for rec in recs:
        assert by_name[rec["name"]]["counters"]["flops"] == rec["flops"]


# ---------------------------------------------------------------------------
# LatencyMeter + the per-sample observe channel
# ---------------------------------------------------------------------------

def _observing_family(reg, latencies, slo_extra=()):
    """A body that plays back a fixed latency trace through
    state.observe — the serve scope's shape without a model."""
    @benchmark(name="obs", scope="t", registry=reg)
    def obs(state):
        while state.keep_running():
            for i, lat in enumerate(list(latencies) + list(slo_extra)):
                state.observe({"latency_s": lat, "ttft_s": lat / 2.0,
                               "queue_depth": i % 4})
    obs.set_iterations(1)
    return obs


def test_latency_meter_reports_tail_counters():
    from repro.core.quantile import percentile
    reg = BenchmarkRegistry()
    trace = [0.001 * (i + 1) for i in range(20)]
    _observing_family(reg, trace)
    doc = run_benchmarks(
        reg.all(), RunOptions(meters=["wall", "cpu", "latency"]),
        progress=False)
    rec = _records(doc)[0]
    for q in ("p50", "p90", "p99", "p999"):
        assert rec[f"latency_{q}_s"] > 0
    assert rec["latency_p50_s"] == pytest.approx(percentile(trace, 0.50))
    assert rec["latency_p999_s"] == pytest.approx(percentile(trace, 0.999))
    assert rec["ttft_p50_s"] == pytest.approx(rec["latency_p50_s"] / 2.0)
    assert rec["requests_completed"] == 20.0
    assert rec["queue_depth_mean"] == pytest.approx(
        sum(i % 4 for i in range(20)) / 20.0)
    assert rec["goodput_rps"] > 0                 # no SLO: all count as good
    assert "slo_attainment" not in rec            # only reported under an SLO


def test_latency_meter_honors_slo():
    """--slo-ms reaches the meter through RunOptions: goodput counts
    only requests at-or-under the objective, attainment is their
    fraction."""
    reg = BenchmarkRegistry()
    _observing_family(reg, [0.005] * 3, slo_extra=[0.050])    # 3 fast, 1 slow
    doc = run_benchmarks(
        reg.all(),
        RunOptions(meters=["wall", "cpu", "latency"], slo_ms=10.0),
        progress=False)
    rec = _records(doc)[0]
    assert rec["slo_attainment"] == pytest.approx(0.75)
    assert rec["requests_completed"] == 4.0
    # goodput excludes the SLO-violating request
    assert rec["goodput_rps"] == pytest.approx(
        0.75 * rec["requests_completed"] / (rec["real_time"] / 1e6),
        rel=1e-6)


def test_observe_without_observer_is_a_noop():
    """Bodies can observe unconditionally: with no observing meter the
    sample is dropped, and observe still returns it for in-place use."""
    from repro.core.benchmark import State
    st = State(max_iterations=1)
    sample = {"latency_s": 0.1}
    assert st.observe(sample) is sample


def test_observe_channel_dispatches_to_every_meter():
    from repro.core.benchmark import State
    from repro.core.measure import Meter

    class Capture(Meter):
        name = "capture"

        def __init__(self):
            self.samples = []

        def observe(self, state, sample):
            self.samples.append(dict(sample))

    a, b = Capture(), Capture()
    stack = MeterStack([a, b])
    st = State(max_iterations=1)
    stack.begin(st)
    st.observe({"latency_s": 1.0})
    st.observe({"latency_s": 2.0})
    assert a.samples == b.samples == [{"latency_s": 1.0},
                                      {"latency_s": 2.0}]
