"""Core utilities: errorcheck (CUDA-check analogue), flags, logging."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ScopeError, check_compiles, check_finite,
                        check_shape, sync)
from repro.core.flags import FlagRegistry
from repro.core.logging import Timer, get_logger


def test_check_finite_passes_and_raises():
    check_finite({"a": jnp.ones(3)})
    with pytest.raises(ScopeError, match="non-finite"):
        check_finite({"a": jnp.asarray([1.0, float("nan")])}, where="here")


def test_check_shape():
    check_shape(jnp.ones((2, 3)), (2, 3))
    with pytest.raises(ScopeError, match="shape mismatch"):
        check_shape(jnp.ones((2, 3)), (3, 2))


def test_check_compiles_catches_bad_program():
    def good(x):
        return x + 1
    assert check_compiles(good, jnp.ones(3)) is not None

    def bad(x):
        return x @ jnp.ones((5, 5))       # shape error at lowering
    with pytest.raises(ScopeError, match="compilation failed"):
        check_compiles(bad, jnp.ones((3, 3)))


def test_sync_returns_value():
    x = sync(jnp.ones(4) * 2)
    np.testing.assert_array_equal(np.asarray(x), 2.0)


def test_flag_registry_types_and_duplicates():
    f = FlagRegistry()
    f.declare("a/x", type=int, default=1, owner="a")
    f.declare("a/flag", is_bool=True, default=False, owner="a")
    with pytest.raises(ValueError, match="already declared"):
        f.declare("a/x", owner="b")
    f.parse(["--a.x", "5", "--a.flag"])
    assert f.get("a/x") == 5
    assert f.get("a/flag") is True
    assert f.get("missing", 9) == 9


def test_timer_and_logger():
    log = get_logger("test")
    with Timer() as t:
        sum(range(1000))
    assert t.elapsed >= 0
    log.info("ok")                        # no crash, handler configured
