"""Optional-``hypothesis`` shim for property-based tests.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt) and is
not baked into every container this suite runs in.  Importing it at module
scope used to abort collection of six test modules with
``ModuleNotFoundError``; instead, test modules import ``given`` /
``settings`` / ``st`` from here:

  * hypothesis installed — re-exports the real objects, property tests run;
  * hypothesis missing  — ``@given`` becomes a skip marker so only the
    property-based tests degrade to skips while the plain tests in the
    same module keep running (the ``pytest.importorskip`` behaviour, but
    scoped per-test instead of per-module).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade property tests to skips
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction; value is never drawn."""

        def __getattr__(self, name):
            def make(*args, **kwargs):
                return self
            return make

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
