"""Checkpoint store/manager: roundtrip, atomicity, GC, corruption, reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              save_checkpoint)


def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = tree()
    p = save_checkpoint(str(tmp_path / "ck"), t, step=7)
    out, step = load_checkpoint(p, t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_tmp_left(tmp_path):
    p = save_checkpoint(str(tmp_path / "ck"), tree())
    assert os.path.exists(p)
    assert not os.path.exists(p + ".tmp")


def test_checksum_detects_corruption(tmp_path):
    t = tree()
    p = save_checkpoint(str(tmp_path / "ck"), t)
    # corrupt one shard file
    shard = [f for f in os.listdir(p) if f.endswith(".npy")][0]
    data = np.load(os.path.join(p, shard))
    np.save(os.path.join(p, shard), data + 1)
    with pytest.raises(IOError, match="checksum"):
        load_checkpoint(p, t)


def test_manager_keep_k_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, save_interval=10,
                            async_save=False)
    t = tree()
    for step in (10, 20, 30):
        assert mgr.maybe_save(step, t)
    assert mgr.steps() == [20, 30]
    restored, step = mgr.restore_or_init(t, lambda: None)
    assert step == 30


def test_manager_falls_through_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, save_interval=1,
                            async_save=False)
    t = tree()
    mgr.maybe_save(1, t)
    mgr.maybe_save(2, t)
    # corrupt newest
    p = mgr.path_for(2)
    shard = [f for f in os.listdir(p) if f.endswith(".npy")][0]
    np.save(os.path.join(p, shard),
            np.load(os.path.join(p, shard)) + 1)
    restored, step = mgr.restore_or_init(t, lambda: None)
    assert step == 1                      # older but valid


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_interval=1, async_save=True)
    mgr.maybe_save(1, tree())
    mgr.wait()
    assert mgr.latest_step() == 1


def test_restore_into_different_structure_fails(tmp_path):
    p = save_checkpoint(str(tmp_path / "ck"), tree())
    with pytest.raises(KeyError):
        load_checkpoint(p, {"other": jnp.zeros(3)})
