"""repro.core.quantile: exact percentiles, merge invariance, and the
P² streaming estimator's agreement with the exact path."""
import random

import numpy as np
import pytest

from repro.core.quantile import (TAIL_QUANTILES, StreamingQuantile, combine,
                                 percentile, tail_percentiles)


@pytest.mark.parametrize("q", [0.0, 0.25, 0.5, 0.9, 0.99, 1.0])
def test_percentile_matches_numpy_linear(q):
    rng = random.Random(11)
    xs = [rng.uniform(-5, 5) for _ in range(137)]
    assert percentile(xs, q) == pytest.approx(
        float(np.percentile(xs, q * 100.0, method="linear")), abs=1e-12)


def test_percentile_with_duplicates_matches_numpy():
    xs = [1.0, 1.0, 1.0, 2.0, 2.0, 9.0, 9.0, 9.0, 9.0]
    for q in (0.1, 0.5, 0.75, 0.999):
        assert percentile(xs, q) == pytest.approx(
            float(np.percentile(xs, q * 100.0)), abs=1e-12)


def test_percentile_edge_cases():
    assert percentile([3.5], 0.99) == 3.5
    with pytest.raises(ValueError, match="empty"):
        percentile([], 0.5)
    with pytest.raises(ValueError, match="quantile"):
        percentile([1.0], 1.5)
    with pytest.raises(ValueError, match="quantile"):
        percentile([1.0], -0.1)


def test_tail_percentiles_keys_and_prefix():
    xs = list(range(1000))
    out = tail_percentiles(xs, prefix="latency_")
    assert set(out) == {f"latency_{s}_s" for s, _ in TAIL_QUANTILES}
    assert out["latency_p50_s"] <= out["latency_p99_s"] \
        <= out["latency_p999_s"]
    assert tail_percentiles([]) == {}


def test_combine_is_order_and_grain_invariant():
    """The property that keeps latency counters identical across
    --jobs/--shard-grain choices: any regrouping of the same samples
    merges to the byte-identical canonical list."""
    rng = random.Random(5)
    a = [rng.gauss(0, 1) for _ in range(31)]
    b = [rng.gauss(2, 3) for _ in range(17)]
    c = [rng.expovariate(1.0) for _ in range(9)]
    golden = combine(a, b, c)
    assert combine(c, b, a) == golden
    assert combine(combine(b, a), c) == golden
    assert combine(c, combine(a), combine(b)) == golden
    for _, q in TAIL_QUANTILES:
        assert percentile(golden, q) == percentile(combine(b, c, a), q)


def test_streaming_exact_below_five_samples():
    sq = StreamingQuantile(0.9)
    seen = []
    for x in [4.0, 1.0, 3.0, 2.0]:
        sq.observe(x)
        seen.append(x)
        assert sq.value() == percentile(seen, 0.9)
    assert sq.count == 4


def test_streaming_tracks_exact_on_large_stream():
    rng = random.Random(42)
    xs = [rng.expovariate(1.0) for _ in range(20000)]
    for q in (0.5, 0.9, 0.99):
        sq = StreamingQuantile(q)
        for x in xs:
            sq.observe(x)
        exact = percentile(xs, q)
        # P² is an estimator: pin agreement to a few percent of the
        # exact value on a well-behaved heavy-ish tail
        assert sq.value() == pytest.approx(exact, rel=0.05)
        assert sq.count == len(xs)


def test_streaming_constant_and_duplicate_streams():
    sq = StreamingQuantile(0.99)
    for _ in range(500):
        sq.observe(7.25)
    assert sq.value() == 7.25
    dup = StreamingQuantile(0.5)
    for x in [1.0, 2.0] * 300:
        dup.observe(x)
    assert 1.0 <= dup.value() <= 2.0


def test_streaming_validation():
    with pytest.raises(ValueError, match="0 < q < 1"):
        StreamingQuantile(0.0)
    with pytest.raises(ValueError, match="0 < q < 1"):
        StreamingQuantile(1.0)
    with pytest.raises(ValueError, match="no observations"):
        StreamingQuantile(0.5).value()
