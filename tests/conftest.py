"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 device
(the dry-run sets 512 placeholder devices itself, in a subprocess)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
