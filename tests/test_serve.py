"""Serve engine: continuous batching correctness + ragged decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build, get_config
from repro.serve import ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def small():
    cfg = get_config("llama3.2-1b").reduced().override(
        num_layers=2, vocab_size=128)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def greedy_reference(cfg, api, params, prompt, n_tokens):
    """Uniform-batch reference generation (prefill + scalar-pos decode)."""
    toks = jnp.asarray(prompt, jnp.int32)[None]
    cache = api.init_cache(1, 256)
    logits, cache = jax.jit(api.prefill)(params, {"tokens": toks}, cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_tokens - 1):
        logits, cache = jax.jit(api.decode_step)(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


def test_engine_matches_reference_single(small):
    cfg, api, params = small
    prompt = np.arange(1, 11)
    ref = greedy_reference(cfg, api, params, prompt, 6)
    eng = ServeEngine(api, params, ServeConfig(max_batch=2, max_len=256,
                                               prompt_buckets=(16,)))
    eng.submit(prompt, max_tokens=6)
    done = eng.run()
    assert len(done) == 1
    assert done[0].output == ref


def test_engine_mixed_lengths_match_reference(small):
    """Continuous batching with heterogeneous prompts must equal per-
    request generation — the per-slot position clock correctness check."""
    cfg, api, params = small
    prompts = [np.arange(1, 6), np.arange(20, 34), np.arange(3, 12)]
    refs = [greedy_reference(cfg, api, params, p, 5) for p in prompts]
    eng = ServeEngine(api, params, ServeConfig(max_batch=2, max_len=256,
                                               prompt_buckets=(16,)))
    reqs = [eng.submit(p, max_tokens=5) for p in prompts]
    done = eng.run()
    assert len(done) == 3
    by_uid = {r.uid: r.output for r in done}
    for req, ref in zip(reqs, refs):
        assert by_uid[req.uid] == ref, req.uid


def test_engine_throughput_summary(small):
    cfg, api, params = small
    eng = ServeEngine(api, params, ServeConfig(max_batch=2, max_len=256,
                                               prompt_buckets=(16,)))
    for i in range(4):
        eng.submit(np.arange(1, 8), max_tokens=3)
    done = eng.run()
    stats = ServeEngine.summarize(done)
    assert stats["requests"] == 4
    assert stats["tokens"] == 12
    assert stats["throughput_tok_s"] > 0
