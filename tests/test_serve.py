"""Serve engine: continuous batching correctness + ragged decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build, get_config
from repro.serve import ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def small():
    cfg = get_config("llama3.2-1b").reduced().override(
        num_layers=2, vocab_size=128)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def greedy_reference(cfg, api, params, prompt, n_tokens):
    """Uniform-batch reference generation (prefill + scalar-pos decode)."""
    toks = jnp.asarray(prompt, jnp.int32)[None]
    cache = api.init_cache(1, 256)
    logits, cache = jax.jit(api.prefill)(params, {"tokens": toks}, cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n_tokens - 1):
        logits, cache = jax.jit(api.decode_step)(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


def test_engine_matches_reference_single(small):
    cfg, api, params = small
    prompt = np.arange(1, 11)
    ref = greedy_reference(cfg, api, params, prompt, 6)
    eng = ServeEngine(api, params, ServeConfig(max_batch=2, max_len=256,
                                               prompt_buckets=(16,)))
    eng.submit(prompt, max_tokens=6)
    done = eng.run()
    assert len(done) == 1
    assert done[0].output == ref


def test_engine_mixed_lengths_match_reference(small):
    """Continuous batching with heterogeneous prompts must equal per-
    request generation — the per-slot position clock correctness check."""
    cfg, api, params = small
    prompts = [np.arange(1, 6), np.arange(20, 34), np.arange(3, 12)]
    refs = [greedy_reference(cfg, api, params, p, 5) for p in prompts]
    eng = ServeEngine(api, params, ServeConfig(max_batch=2, max_len=256,
                                               prompt_buckets=(16,)))
    reqs = [eng.submit(p, max_tokens=5) for p in prompts]
    done = eng.run()
    assert len(done) == 3
    by_uid = {r.uid: r.output for r in done}
    for req, ref in zip(reqs, refs):
        assert by_uid[req.uid] == ref, req.uid


def test_engine_throughput_summary(small):
    cfg, api, params = small
    eng = ServeEngine(api, params, ServeConfig(max_batch=2, max_len=256,
                                               prompt_buckets=(16,)))
    for i in range(4):
        eng.submit(np.arange(1, 8), max_tokens=3)
    done = eng.run()
    stats = ServeEngine.summarize(done)
    assert stats["requests"] == 4
    assert stats["tokens"] == 12
    assert stats["throughput_tok_s"] > 0


def test_queue_deeper_than_max_batch_refills_slots(small):
    """5 requests through a 2-slot pool: freed slots must refill from
    the queue until everything drains (no head-of-line blocking)."""
    cfg, api, params = small
    eng = ServeEngine(api, params, ServeConfig(max_batch=2, max_len=256,
                                               prompt_buckets=(16,)))
    reqs = [eng.submit(np.arange(1, 6 + i), max_tokens=3) for i in range(5)]
    done = eng.run()
    assert sorted(r.uid for r in done) == sorted(r.uid for r in reqs)
    assert all(len(r.output) == 3 for r in done)
    assert all(r.done_at is not None for r in done)
    # queue-depth evidence: the first step sees all 5 in flight/queued,
    # and depth only drains as slots free and refill
    assert eng.queue_depth_log[0] == 5
    assert max(eng.queue_depth_log) == 5
    assert min(eng.queue_depth_log) >= 1


def test_eos_frees_slot_midrun(small):
    """An EOS hit mid-generation must finish the request early AND free
    its slot for the queued request behind it."""
    cfg, api, params = small
    prompt = np.arange(1, 11)
    ref = greedy_reference(cfg, api, params, prompt, 8)
    eos = ref[3]
    # engine checks EOS only on decode-produced tokens (ref[1:])
    stop = next(i for i in range(1, len(ref)) if ref[i] == eos)
    eng = ServeEngine(api, params, ServeConfig(max_batch=1, max_len=256,
                                               prompt_buckets=(16,)))
    first = eng.submit(prompt, max_tokens=50, eos_id=int(eos))
    second = eng.submit(np.arange(30, 37), max_tokens=3)
    done = eng.run()
    assert [r.uid for r in done] == [first.uid, second.uid]
    assert first.output == ref[:stop + 1]          # stopped early, at EOS
    assert len(first.output) < 50
    assert len(second.output) == 3                 # the freed slot served it
    assert first.done_at <= second.done_at


def test_oversize_prompt_raises_actionably(small):
    cfg, api, params = small
    eng = ServeEngine(api, params, ServeConfig(max_batch=1, max_len=256,
                                               prompt_buckets=(16,)))
    with pytest.raises(ValueError, match="prompt_buckets"):
        eng.submit(np.arange(1, 30))
    assert not eng.queue                           # nothing half-enqueued


def test_prompt_exceeding_max_len_raises(small):
    cfg, api, params = small
    eng = ServeEngine(api, params, ServeConfig(max_batch=1, max_len=16,
                                               prompt_buckets=(32,)))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.arange(1, 21))
    assert not eng.queue


def test_max_len_exhaustion_truncates_and_terminates(small):
    """A request asking for more tokens than the slot's cache can hold
    must terminate (marked truncated), not overrun the static cache or
    spin forever."""
    cfg, api, params = small
    eng = ServeEngine(api, params, ServeConfig(max_batch=1, max_len=16,
                                               prompt_buckets=(16,)))
    req = eng.submit(np.arange(1, 9), max_tokens=100)     # 8-token prompt
    done = eng.run()
    assert [r.uid for r in done] == [req.uid]
    assert req.truncated
    assert req.done_at is not None
    assert len(req.output) == 16 - 8               # filled the cache exactly


def test_summarize_empty_and_all_failed_batches():
    from repro.serve.engine import Request
    assert ServeEngine.summarize([]) == {}
    dead = [Request(uid=i, prompt=np.arange(3), submitted_at=float(i))
            for i in (1, 2)]                       # never reached done_at
    stats = ServeEngine.summarize(dead)
    assert stats["requests"] == 2
    assert stats["ttft_mean_s"] == 0.0
    assert stats["latency_mean_s"] == 0.0
    assert stats["throughput_tok_s"] == 0.0


class _SlowPrefillApi:
    """ModelApi wrapper whose prefill drags a long serial compute chain
    into the compiled program — TTFT-visible latency without changing
    which tokens come out (the chain perturbs logits by a factor of
    (1 + ~1e-34), far below any logit gap)."""

    def __init__(self, api, chain=48, dim=192):
        self._api = api
        self.cfg = api.cfg
        self._chain = chain
        self._dim = dim

    def init(self, *a, **k):
        return self._api.init(*a, **k)

    def init_cache(self, *a, **k):
        return self._api.init_cache(*a, **k)

    def prefill(self, params, batch, cache, logit_pos=None):
        logits, cache = self._api.prefill(params, batch, cache,
                                          logit_pos=logit_pos)
        x = jnp.full((self._dim, self._dim), 0.5, jnp.float32)
        for _ in range(self._chain):
            x = jnp.sin(x @ x)                     # bounded: never inf/NaN
        return logits * (1.0 + x.mean() * 1e-34), cache


def test_fenced_ttft_not_below_unfenced(small):
    """The async-dispatch regression satellite: with fence_timestamps
    off, first_token_at is stamped when the prefill *dispatch* returns;
    with it on, after the logits are actually delivered.  On a model
    with genuinely slow prefill the fenced TTFT must be the larger one
    — if it isn't, the stamp is measuring enqueue, not delivery."""
    cfg, api, params = small
    slow = _SlowPrefillApi(api)
    eng = ServeEngine(slow, params, ServeConfig(max_batch=1, max_len=256,
                                                prompt_buckets=(16,)))
    prompt = np.arange(1, 11)
    eng.submit(prompt, max_tokens=2)
    eng.run()                                      # warm: compile both paths

    def ttft(fenced):
        eng.cfg.fence_timestamps = fenced
        req = eng.submit(prompt, max_tokens=2)
        eng.run()
        return req.first_token_at - req.submitted_at

    unfenced = min(ttft(False) for _ in range(3))
    fenced = min(ttft(True) for _ in range(3))
    assert fenced >= unfenced


def test_single_slot_engine_matches_reference(small):
    """max_batch=1 regression: the cache splice must handle a pool whose
    batch dim equals the row's (there is no axis-size difference to find)
    — a single-slot engine used to decode over a zero cache."""
    cfg, api, params = small
    prompt = np.arange(1, 11)
    ref = greedy_reference(cfg, api, params, prompt, 6)
    eng = ServeEngine(api, params, ServeConfig(max_batch=1, max_len=256,
                                               prompt_buckets=(16,)))
    eng.submit(prompt, max_tokens=6)
    done = eng.run()
    assert done[0].output == ref
