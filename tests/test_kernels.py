"""Per-kernel allclose vs the pure-jnp oracle (interpret=True on CPU).

Sweeps shapes and dtypes per the deliverable; the BlockSpec tilings are
also structurally asserted (MXU/VMEM alignment).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.histogram import histogram, histogram_ref
from repro.kernels.matmul import matmul, matmul_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.ssd_scan import ssd, ssd_reference


@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (128, 64, 96, 64, 32, 32),
    (256, 256, 256, 128, 128, 128),
    (64, 128, 64, 64, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel(m, k, n, bm, bk, bn, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.5).astype(dtype)
    y = (jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.5).astype(dtype)
    out = matmul(x, y, bm=bm, bk=bk, bn=bn)
    ref = matmul_ref(x, y)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("S,H,K,D,bq,bk", [
    (128, 4, 2, 32, 32, 32),
    (64, 2, 2, 64, 64, 64),
    (256, 4, 1, 16, 64, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(S, H, K, D, bq, bk, causal, dtype):
    q = (jax.random.normal(jax.random.PRNGKey(0), (2, S, H, D))).astype(dtype)
    k = (jax.random.normal(jax.random.PRNGKey(1), (2, S, K, D))).astype(dtype)
    v = (jax.random.normal(jax.random.PRNGKey(2), (2, S, K, D))).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("rows,d,br", [(64, 128, 16), (256, 512, 64),
                                       (32, 1024, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(rows, d, br, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (rows, d))).astype(dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (d,)) + 1.0
    out = rmsnorm(x, s, br=br)
    ref = rmsnorm_ref(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("n,bins,chunk", [(4096, 64, 512), (8192, 256, 1024),
                                          (1024, 16, 256)])
def test_histogram_kernel(n, bins, chunk):
    x = jax.random.randint(jax.random.PRNGKey(0), (n,), 0, bins)
    out = histogram(x, bins, chunk=chunk)
    ref = histogram_ref(x, bins)
    assert int(out.sum()) == n
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("l,h,chunk", [(32, 2, 8), (64, 3, 16), (128, 1, 32)])
def test_ssd_kernel(l, h, chunk):
    b, p, n = 2, 8, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, l, h, p)) * 0.4
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(3), (b, l, 1, n)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(4), (b, l, 1, n)) * 0.3
    D = jnp.ones((h,))
    y, s = ssd(x, dt, A, Bm, Cm, D, chunk=chunk)
    yr, sr = ssd_reference(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=3e-5)


def test_tilings_are_tpu_aligned():
    """Structural check: default blocks are MXU-aligned multiples of 128
    and fit comfortably in v5e VMEM."""
    from repro.core.sysinfo import TPU_V5E
    vmem = TPU_V5E["vmem_bytes"]
    bm = bn = bk = 512
    assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
    working = (bm * bk + bk * bn) * 2 + bm * bn * 4
    assert working < vmem / 8
    bq = bk_ = 512
    D = 128
    fa = (2 * bq * D + 2 * bk_ * D) * 2 + bq * D * 4 + bq * bk_ * 4
    assert fa < vmem / 8
