"""Work-plan layer: instance enumeration, stable IDs, cost hints, LPT
binning, and the `python -m repro plan` CLI (repro.core.plan)."""
import json

import pytest

from repro.core import baseline as bl
from repro.core.flags import FlagRegistry
from repro.core.hooks import HookChain
from repro.core.plan import (Plan, PlanItem, build_plan, instance_id,
                             load_cost_hints, scope_worklist)
from repro.core.registry import BenchmarkRegistry
from repro.core.runner import RunOptions, run_benchmarks
from repro.core.scope import Scope, ScopeManager


def make_mgr(modules):
    mgr = ScopeManager(registry=BenchmarkRegistry(), flags=FlagRegistry(),
                       hooks=HookChain())
    mgr.load(modules)
    mgr.register_all()
    return mgr


def item(name, cost=None, scope="s", module="m"):
    return PlanItem(instance_id=instance_id(name), name=name, scope=scope,
                    family=name.rsplit("/", 1)[0] if "/" in name else name,
                    module=module, arg_set=(), cost=cost)


# ---------------------------------------------------------------------------
# enumeration + stable IDs
# ---------------------------------------------------------------------------

def test_build_plan_enumerates_in_document_order():
    """Plan order == the benchmark order of an inline scope-grained run —
    the invariant that keeps merged.json deterministic across grains."""
    mgr = make_mgr(["repro.scopes.example_scope"])
    seq = run_benchmarks(mgr.registry.filter(".*"),
                         RunOptions(min_time=0.001), progress=False)
    plan = build_plan(mgr, mgr.registry)
    assert [i.name for i in plan.items] == \
        [r["name"] for r in seq["benchmarks"]]
    assert all(i.scope == "example" for i in plan.items)
    assert all(i.module == "repro.scopes.example_scope"
               for i in plan.items)
    # arg sets round-trip: saxpy sweep is recorded per instance
    saxpy = [i for i in plan.items if i.family == "example/saxpy"]
    assert [i.arg_set for i in saxpy] == \
        [(256,), (1024,), (4096,), (16384,), (65536,)]


def test_instance_ids_stable_unique_and_fs_safe():
    mgr = make_mgr(["repro.scopes.example_scope"])
    a = build_plan(mgr, mgr.registry)
    b = build_plan(mgr, mgr.registry)
    ids = [i.instance_id for i in a.items]
    assert ids == [i.instance_id for i in b.items]   # stable across builds
    assert len(set(ids)) == len(ids)                 # unique
    for iid in ids:
        assert "/" not in iid and ":" not in iid     # filesystem-safe
    # sanitization alone would collide; the digest must disambiguate
    assert instance_id("a/b:1") != instance_id("a/b_1")
    assert instance_id("x") == instance_id("x")


def test_plan_item_meta_round_trips():
    it = item("s/f/2", cost=1.5)
    assert PlanItem.from_meta(json.loads(json.dumps(it.meta()))) == it


def test_scope_worklist_skips_disabled_and_unavailable():
    mgr = make_mgr(["repro.scopes.example_scope", "no.such.module"])
    mgr.add_scope(Scope(name="ext"))
    assert scope_worklist(mgr) == [
        ("example", "repro.scopes.example_scope"), ("ext", "<external>")]
    mgr.configure(disable=["example"])
    assert scope_worklist(mgr) == [("ext", "<external>")]
    # plan construction honors the same selection: example's registered
    # benchmarks no longer enumerate once the scope is disabled
    assert build_plan(mgr, mgr.registry).items == []


# ---------------------------------------------------------------------------
# cost hints + LPT binning
# ---------------------------------------------------------------------------

def test_lpt_bins_balance_by_cost():
    plan = Plan(items=[item("s/a", 4.0), item("s/b", 3.0),
                       item("s/c", 2.0), item("s/d", 1.0)])
    bins = plan.bins(2)
    loads = [sum(plan.cost_of(i) for i in b) for b in bins]
    assert sorted(loads) == [5.0, 5.0]       # LPT: {4,1} and {3,2}
    assert [i.name for i in bins[0]] == ["s/a", "s/d"]
    assert [i.name for i in bins[1]] == ["s/b", "s/c"]


def test_bins_preserve_plan_order_and_drop_empty():
    plan = Plan(items=[item(f"s/{k}") for k in "abcde"])
    bins = plan.bins(3)
    for b in bins:
        names = [i.name for i in b]
        assert names == sorted(names)        # document order within a bin
    assert plan.bins(10) and all(len(b) == 1 for b in plan.bins(10))
    assert len(plan.bins(10)) == 5           # empty bins dropped
    assert [i.name for b in plan.bins(1) for i in b] == \
        [i.name for i in plan.items]


def test_bins_deterministic():
    plan = Plan(items=[item(f"s/{k}", cost=1.0) for k in "abcdef"])
    assert [[i.name for i in b] for b in plan.bins(3)] == \
        [[i.name for i in b] for b in plan.bins(3)]


def test_default_cost_is_median_of_hints():
    mgr = make_mgr(["repro.scopes.example_scope"])
    hints = {"example/noop": 2.0, "example/saxpy/n:256": 6.0}
    plan = build_plan(mgr, mgr.registry, cost_hints=hints)
    by = {i.name: i for i in plan.items}
    assert by["example/noop"].cost == 2.0
    assert by["example/saxpy/n:1024"].cost is None
    assert plan.cost_of(by["example/saxpy/n:1024"]) == 4.0  # median hint


def test_load_cost_hints_from_gb_document(tmp_path):
    doc = {"context": {}, "benchmarks": [
        {"name": "s/a", "run_name": "s/a", "run_type": "iteration",
         "repetitions": 1, "repetition_index": 0, "threads": 1,
         "iterations": 10, "real_time": 2000.0, "cpu_time": 2000.0,
         "time_unit": "us"}]}
    p = tmp_path / "base.json"
    p.write_text(json.dumps(doc))
    hints = load_cost_hints(str(p))
    assert hints["s/a"] == pytest.approx(2e-3)    # us → seconds


def test_load_cost_hints_prefers_manifest_durations(tmp_path):
    run = tmp_path / "r"
    run.mkdir()
    (run / "manifest.json").write_text(json.dumps({
        "run_id": "r", "grain": "benchmark",
        "items": [
            {"instance_id": "x", "name": "s/a", "status": "ok",
             "duration_s": 7.5, "shard": "shards/x.json"},
            {"instance_id": "y", "name": "s/b", "status": "error",
             "duration_s": 1.0, "shard": "shards/y.json"},
        ]}))
    hints = load_cost_hints(str(run))
    assert hints == {"s/a": 7.5}   # wall durations; failed items excluded


# ---------------------------------------------------------------------------
# baseline/scopeplot read instance-sharded run directories
# ---------------------------------------------------------------------------

def _instance_shard(name, t_us):
    return {"context": {"instance": {"instance_id": instance_id(name),
                                     "name": name, "status": "ok"}},
            "benchmarks": [{
                "name": name, "run_name": name, "run_type": "iteration",
                "repetitions": 1, "repetition_index": 0, "threads": 1,
                "iterations": 1, "real_time": t_us, "cpu_time": t_us,
                "time_unit": "us"}]}


def _write_instance_run_dir(run, names, drop_manifest=False):
    shards = run / "shards"
    shards.mkdir(parents=True)
    items = []
    for n in names:
        iid = instance_id(n)
        (shards / f"{iid}.json").write_text(
            json.dumps(_instance_shard(n, 1.0)))
        items.append({"instance_id": iid, "name": n, "status": "ok",
                      "shard": f"shards/{iid}.json"})
    if not drop_manifest:
        (run / "manifest.json").write_text(json.dumps(
            {"run_id": run.name, "grain": "benchmark", "items": items}))


def test_load_document_reads_interrupted_instance_run_dir(tmp_path):
    """No merged.json (killed mid-run): shards/*.json are concatenated in
    manifest (plan) order, manifest.json itself is not mistaken for a
    shard."""
    run = tmp_path / "r1"
    # deliberately non-alphabetical plan order — manifest must win
    _write_instance_run_dir(run, ["s/zeta", "s/alpha", "s/mid"])
    doc = bl.load_document(str(run))
    assert [r["name"] for r in doc["benchmarks"]] == \
        ["s/zeta", "s/alpha", "s/mid"]


def test_load_document_instance_dir_without_manifest(tmp_path):
    run = tmp_path / "r2"
    _write_instance_run_dir(run, ["s/b", "s/a"], drop_manifest=True)
    doc = bl.load_document(str(run))
    assert sorted(r["name"] for r in doc["benchmarks"]) == ["s/a", "s/b"]


def test_scopeplot_loads_instance_run_dir(tmp_path):
    from repro.scopeplot import load
    run = tmp_path / "r3"
    _write_instance_run_dir(run, ["ex/b/1", "ex/b/2", "io/c"])
    bf = load(str(run))
    assert [r.name for r in bf] == ["ex/b/1", "ex/b/2", "io/c"]
    assert bf.scope_names() == ["ex", "io"]
