"""Gradient compression: codecs + error-feedback contraction property."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.distributed.compression import (bf16_compress, bf16_decompress,
                                           ef_compress_tree, int8_dequantize,
                                           int8_quantize)


def test_bf16_roundtrip_close():
    x = {"g": jnp.linspace(-3, 3, 1000)}
    y = bf16_decompress(bf16_compress(x))
    np.testing.assert_allclose(np.asarray(y["g"]), np.asarray(x["g"]),
                               atol=2e-2)


@given(st.integers(1, 2000), st.floats(0.1, 100.0))
@settings(max_examples=20, deadline=None)
def test_int8_bounded_error(n, scale):
    x = jnp.asarray(np.random.default_rng(n).normal(0, scale, n),
                    jnp.float32)
    packed = int8_quantize(x)
    y = int8_dequantize(packed, x.shape)
    # per-block error bounded by scale/254 * blockmax
    err = np.abs(np.asarray(x - y))
    assert err.max() <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_error_feedback_reduces_bias():
    """EF: accumulated decoded updates track accumulated true gradients."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(256)
    decoded_sum = np.zeros(256)
    err = None
    for step in range(50):
        g = {"w": jnp.asarray(rng.normal(0, 1, 256), jnp.float32)}
        true_sum += np.asarray(g["w"])
        packed, err = ef_compress_tree(g, err)
        decoded = int8_dequantize(packed["w"], (256,))
        decoded_sum += np.asarray(decoded)
    # without EF the bias would accumulate; with EF the residual is bounded
    # by one step's quantization error
    resid = np.abs(true_sum - decoded_sum)
    assert resid.max() < 0.2


def test_ef_error_state_bounded():
    rng = np.random.default_rng(1)
    err = None
    for step in range(30):
        g = {"w": jnp.asarray(rng.normal(0, 1, 128), jnp.float32)}
        _, err = ef_compress_tree(g, err)
    assert float(jnp.abs(err["w"]).max()) < 1.0
