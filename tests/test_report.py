"""Report pipeline: golden report.md from a fixture run dir, spec
round-trips for every plot type, SpecError line numbers, batch-mode
staleness, and the two-run end-to-end trend (repro.scopeplot.report).

Regenerate the golden after an intentional report-format change::

    REPORT_GOLDEN_UPDATE=1 PYTHONPATH=src python -m pytest tests/test_report.py
"""
import json
import os

import pytest
import yaml

from repro.core import history as hist
from repro.scopeplot.plot import (PLOT_TYPES, SpecError, is_stale,
                                  load_spec, render_spec,
                                  render_spec_dir)
from repro.scopeplot.report import (generate_history_report,
                                    generate_run_report, report_main)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "report_golden.md")

CTX = {"date": "2026-07-31T00:00:00", "host_name": "fixturehost",
       "machine": "x86_64", "num_cpus": 8, "jax_version": "0.0-test",
       "backend": "cpu", "device_count": 1, "device_kind": "cpu",
       "target_hardware": "tpu_v5e", "scope_version": "1.0.0-jax"}


def gb_doc(run_id, means_us, date="2026-07-31T00:00:00"):
    ctx = dict(CTX, run_id=run_id, date=date)
    ctx["shards"] = [{"scope": "s", "module": "m", "status": "ok",
                      "duration_s": 0.5}]
    return {"context": ctx, "benchmarks": [
        {"name": n, "run_name": n, "run_type": "iteration",
         "repetitions": 1, "repetition_index": 0, "threads": 1,
         "iterations": 10, "real_time": us, "cpu_time": us,
         "time_unit": "us"} for n, us in means_us.items()]}


def fixture_run_dir(tmp_path):
    """Two deterministic runs recorded in history; r2 persisted."""
    results = tmp_path / "results"
    run_dir = results / "r2"
    run_dir.mkdir(parents=True)
    doc1 = gb_doc("r1", {"s/a/n:1": 2.2, "s/a/n:2": 4.0},
                  date="2026-07-30T00:00:00")
    doc2 = gb_doc("r2", {"s/a/n:1": 2.0, "s/a/n:2": 4.0})
    hist.append_run(str(results), doc1)
    hist.append_run(str(results), doc2)
    (run_dir / "merged.json").write_text(json.dumps(doc2, indent=2))
    return run_dir


# ---------------------------------------------------------------------------
# golden file
# ---------------------------------------------------------------------------

def test_report_md_matches_golden(tmp_path):
    """The Markdown report from a fixed run dir is byte-stable —
    everything in it derives from the run artifacts, never from the
    machine or clock the report was generated on."""
    run_dir = fixture_run_dir(tmp_path)
    paths = generate_run_report(str(run_dir))
    got = open(paths["md"]).read()
    if os.environ.get("REPORT_GOLDEN_UPDATE"):
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            f.write(got)
        pytest.skip("golden updated")
    assert got == open(GOLDEN).read()


def test_report_artifacts(tmp_path):
    run_dir = fixture_run_dir(tmp_path)
    paths = generate_run_report(str(run_dir))
    out = run_dir / "report"
    assert paths["html"] == str(out / "index.html")
    for f in ("index.html", "report.md", "s_times.png", "s_trend.png",
              "s_speedup.png"):
        assert (out / f).exists(), f
    html = open(paths["html"]).read()
    assert '<img src="s_times.png"' in html
    assert "Drift watch" in html
    # generated specs are real, re-renderable ScopePlot specs
    specs = sorted(os.listdir(out / "specs"))
    assert specs == ["s_speedup.yaml", "s_times.yaml", "s_trend.yaml"]
    for result in render_spec_dir(str(out / "specs"), force=True):
        assert result[2] == "rendered", result


def test_report_on_older_run_ignores_later_runs(tmp_path):
    """Reporting run r1 after r2 was recorded must compare r1 against
    the runs *before* it — never present r2-vs-window data as r1's."""
    results = fixture_run_dir(tmp_path).parent
    run1 = results / "r1"
    run1.mkdir()
    (run1 / "merged.json").write_text(json.dumps(
        gb_doc("r1", {"s/a/n:1": 2.2, "s/a/n:2": 4.0},
               date="2026-07-30T00:00:00"), indent=2))
    paths = generate_run_report(str(run1))
    md = open(paths["md"]).read()
    # nothing recorded before r1: no speedup plot, no drift comparison
    assert "speedup" not in md
    assert "Needs at least two recorded runs" in md
    assert "`r2`" not in md.split("## Drift watch")[1]
    # the trend spec reads a materialized history *prefix* — r2 (recorded
    # after r1) must not appear in r1's trend plot
    trend = load_spec(str(run1 / "report" / "specs" / "s_trend.yaml"))
    scoped = hist.load_history(os.path.join(
        str(run1 / "report" / "specs"), trend["series"][0]["input_file"]))
    assert hist.run_ids(scoped) == ["r1"]


def test_grouped_bar_keeps_duplicate_categories(tmp_path):
    """An x category repeated within one series is disambiguated, not
    silently collapsed to the last value."""
    doc = gb_doc("r", {"s/a/n:1": 2.0, "s/a/n:2": 4.0,
                       "s/b/n:1": 3.0, "s/b/n:2": 5.0})
    src = tmp_path / "r.json"
    src.write_text(json.dumps(doc))
    from repro.scopeplot.plot import _draw_grouped_bar
    import matplotlib.pyplot as plt
    fig, ax = plt.subplots()
    _draw_grouped_bar(ax, {"series": [{"input_file": str(src),
                                       "xfield": "n",
                                       "yfield": "real_time"}]}, ".")
    labels = [t.get_text() for t in ax.get_xticklabels()]
    plt.close(fig)
    assert labels == ["1", "2", "1 (2)", "2 (2)"]


def test_history_report(tmp_path):
    run_dir = fixture_run_dir(tmp_path)
    results = run_dir.parent
    paths = generate_history_report(str(results / "history.jsonl"))
    md = open(paths["md"]).read()
    assert "| r1 |" in md and "| r2 |" in md
    assert (results / "report" / "s_trend.png").exists()


def test_report_main_cli(tmp_path, capsys):
    run_dir = fixture_run_dir(tmp_path)
    results = str(run_dir.parent)
    assert report_main(["r2", "--results-dir", results]) == 0
    out = capsys.readouterr().out.splitlines()
    assert out[0].endswith("index.html") and out[1].endswith("report.md")
    assert report_main(["history", "--results-dir", results]) == 0
    capsys.readouterr()
    # unknown run: error names the known runs
    assert report_main(["nope", "--results-dir", results]) == 2
    assert "r2" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# spec round-trip: every plot type through dump → load_spec → render
# ---------------------------------------------------------------------------

def _spec_for(ptype, src, history_file):
    spec = {"title": f"t-{ptype}", "type": ptype,
            "series": [{"label": "a", "input_file": src,
                        "xfield": "n", "yfield": "real_time"}]}
    if ptype == "speedup":
        spec["baseline"] = {"input_file": src}
    if ptype == "timeseries":
        spec["series"] = [{"label": "a", "input_file": history_file,
                           "regex": "^s/"}]
    return spec


@pytest.mark.parametrize("ptype", PLOT_TYPES)
def test_spec_roundtrip_each_plot_type(tmp_path, ptype):
    run_dir = fixture_run_dir(tmp_path)
    src = str(run_dir / "merged.json")
    history_file = str(run_dir.parent / "history.jsonl")
    spec = _spec_for(ptype, src, history_file)
    spec["output"] = str(tmp_path / f"{ptype}.png")
    spec_path = tmp_path / f"{ptype}.yaml"
    spec_path.write_text(yaml.safe_dump(spec))
    loaded = load_spec(str(spec_path))
    assert loaded["type"] == ptype
    out = render_spec(loaded)
    assert os.path.exists(out) and os.path.getsize(out) > 0


# ---------------------------------------------------------------------------
# load_spec error contract (documented in docs/scopeplot.md)
# ---------------------------------------------------------------------------

def _write_spec(tmp_path, text):
    p = tmp_path / "spec.yaml"
    p.write_text(text)
    return str(p)


def test_load_spec_unknown_type_line_numbered(tmp_path):
    p = _write_spec(tmp_path,
                    "title: x\ntype: pie\nseries:\n  - input_file: r.json\n")
    with pytest.raises(SpecError) as e:
        load_spec(p)
    assert f"{p}:2: " in str(e.value)
    assert "unknown plot type 'pie'" in str(e.value)
    for t in PLOT_TYPES:
        assert t in str(e.value)           # error lists the valid types
    assert isinstance(e.value, ValueError)  # old except clauses still work


def test_load_spec_output_and_series_validation(tmp_path):
    p = _write_spec(tmp_path, "type: line\noutput: [a, b]\n"
                              "series:\n  - input_file: r.json\n")
    with pytest.raises(SpecError, match=r"spec\.yaml:2: 'output'"):
        load_spec(p)
    p = _write_spec(tmp_path, "title: x\ntype: line\n")
    with pytest.raises(SpecError, match="non-empty 'series'"):
        load_spec(p)
    p = _write_spec(tmp_path, "type: line\nseries:\n  - label: a\n")
    with pytest.raises(SpecError, match=r"series\[0\] needs an 'input_file'"):
        load_spec(p)
    p = _write_spec(tmp_path, "type: speedup\nseries:\n"
                              "  - input_file: r.json\n")
    with pytest.raises(SpecError, match="needs a 'baseline'"):
        load_spec(p)
    p = _write_spec(tmp_path, "[1, 2]\n")
    with pytest.raises(SpecError, match="must be a YAML mapping"):
        load_spec(p)


def test_load_spec_invalid_yaml(tmp_path):
    p = _write_spec(tmp_path, "type: line\n  bad indent: [\n")
    with pytest.raises(SpecError, match="invalid YAML"):
        load_spec(p)


# ---------------------------------------------------------------------------
# batch mode: only stale specs re-render
# ---------------------------------------------------------------------------

def test_batch_renders_only_stale(tmp_path):
    run_dir = fixture_run_dir(tmp_path)
    src = run_dir / "merged.json"
    specs = tmp_path / "specs"
    specs.mkdir()
    for name in ("one", "two"):
        spec = {"type": "bar", "output": f"{name}.png",
                "series": [{"input_file": str(src), "xfield": "n",
                            "yfield": "real_time"}]}
        (specs / f"{name}.yaml").write_text(yaml.safe_dump(spec))
    first = render_spec_dir(str(specs))
    assert [s for _, _, s in first] == ["rendered", "rendered"]
    second = render_spec_dir(str(specs))
    assert [s for _, _, s in second] == ["fresh", "fresh"]
    # touching one data dependency makes only dependents stale
    future = os.path.getmtime(specs / "one.png") + 60
    os.utime(src, (future, future))
    spec = load_spec(str(specs / "one.yaml"))
    assert is_stale(str(specs / "one.yaml"), spec)
    third = render_spec_dir(str(specs))
    assert [s for _, _, s in third] == ["rendered", "rendered"]
    # a broken spec reports an error but doesn't stop the batch
    (specs / "zz.yaml").write_text("type: pie\nseries: []\n")
    results = render_spec_dir(str(specs), force=True)
    assert [s.split(":")[0] for _, _, s in results] == \
        ["rendered", "rendered", "error"]


# ---------------------------------------------------------------------------
# end-to-end: two orchestrated runs → trend plot shows both
# ---------------------------------------------------------------------------

def test_two_runs_then_report_shows_trend(tmp_path):
    from repro.core.flags import FlagRegistry
    from repro.core.hooks import HookChain
    from repro.core.orchestrate import OrchestratorOptions, execute
    from repro.core.registry import BenchmarkRegistry
    from repro.core.runner import RunOptions
    from repro.core.scope import ScopeManager

    results = str(tmp_path / "results")
    for rid in ("e1", "e2"):
        mgr = ScopeManager(registry=BenchmarkRegistry(),
                           flags=FlagRegistry(), hooks=HookChain())
        mgr.load(["repro.scopes.example_scope"])
        mgr.register_all()
        execute(mgr, mgr.registry, OrchestratorOptions(
            jobs=1, isolate="inline", shard_grain="benchmark",
            run=RunOptions(min_time=0.002), results_dir=results,
            run_id=rid))
    paths = generate_run_report(os.path.join(results, "e2"))
    md = open(paths["md"]).read()
    assert "history: 2 recorded run(s)" in md
    assert "![example: trend across runs](example_trend.png)" in md
    assert "![example: speedup vs previous run](example_speedup.png)" in md
    out = os.path.join(results, "e2", "report")
    assert os.path.getsize(os.path.join(out, "example_trend.png")) > 0
    # the trend spec reads the real history store with both runs in it
    trend = load_spec(os.path.join(out, "specs", "example_trend.yaml"))
    history_file = os.path.join(
        out, "specs", trend["series"][0]["input_file"])
    records = hist.load_history(history_file)
    assert hist.run_ids(records) == ["e1", "e2"]


# ---------------------------------------------------------------------------
# latency counters: verdict columns and the CDF page are strictly opt-in
# ---------------------------------------------------------------------------

def latency_run_dir(tmp_path):
    """A serve-scope run whose records carry the latency meter's
    counters (plus a plain r1 so the trend machinery has history)."""
    results = tmp_path / "results"
    run_dir = results / "r2"
    run_dir.mkdir(parents=True)
    doc1 = gb_doc("r1", {"serve/load/arrival:poisson": 2.2},
                  date="2026-07-30T00:00:00")
    doc2 = gb_doc("r2", {"serve/load/arrival:poisson": 2.0,
                         "serve/load/arrival:bursty": 2.4})
    for rec in doc2["benchmarks"]:
        rec.update({"latency_p50_s": 0.010, "latency_p90_s": 0.020,
                    "latency_p99_s": 0.050, "latency_p999_s": 0.090,
                    "goodput_rps": 31.5, "slo_attainment": 1.0})
    hist.append_run(str(results), doc1)
    hist.append_run(str(results), doc2)
    (run_dir / "merged.json").write_text(json.dumps(doc2, indent=2))
    return run_dir


def test_report_without_latency_counters_omits_latency_columns(tmp_path):
    """The pre-latency report shape is untouched (the golden test pins
    it byte-for-byte; this states the property directly)."""
    run_dir = fixture_run_dir(tmp_path)
    paths = generate_run_report(str(run_dir))
    md = open(paths["md"]).read()
    assert "p99 latency" not in md
    assert "goodput" not in md
    assert "latency" not in "".join(
        os.listdir(os.path.join(str(run_dir), "report", "specs")))


def test_report_with_latency_counters_adds_columns_and_cdf(tmp_path):
    run_dir = latency_run_dir(tmp_path)
    paths = generate_run_report(str(run_dir))
    md = open(paths["md"]).read()
    assert "| p99 latency | goodput |" in md
    assert "31.5 req/s" in md
    assert "serve_latency.png" in md
    out = run_dir / "report"
    assert (out / "serve_latency.png").exists()
    assert (out / "specs" / "serve_latency.yaml").exists()
    # the emitted spec is a real, re-renderable ScopePlot spec
    for result in render_spec_dir(str(out / "specs"), force=True):
        assert result[2] == "rendered", result
